#!/usr/bin/env python3
"""Docs drift check: every CLI flag the docs mention must really exist.

Pure stdlib (CI's gate tier runs it without jax). For every checked
markdown file the script

1. collects ``python -m <module>`` / ``python3 -m <module>`` invocations
   and bare ``path/to/script.py`` mentions, mapping each to a repo file
   (``benchmarks.run`` → ``benchmarks/run.py``, ``repro.obs.export`` →
   ``src/repro/obs/export.py``); an invocation that maps to no file is
   an error (a renamed or deleted entry point);
2. parses each referenced module with ``ast`` — no imports, so modules
   with heavyweight dependencies cost nothing — and collects every
   string literal passed to an ``add_argument(...)`` call;
3. extracts every ``--flag`` token from the markdown (ignoring
   ``ENV=--flag`` forms like ``XLA_FLAGS=--xla_force...``) and requires
   each to exist in the union of the file's referenced parsers.

Reference/planning documents (ISSUE/PAPER/PAPERS/SNIPPETS/CHANGES/
ROADMAP) are excluded: they quote external code and future work, not
the current CLI surface. Exit status 0 = clean, 1 = drift (one line
per offending ``file:line``).
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# not user docs: planning / paper / exemplar material quotes flags and
# invocations that are not (yet) part of this repo's CLI surface
SKIP_NAMES = {"ISSUE.md", "PAPER.md", "PAPERS.md", "SNIPPETS.md",
              "CHANGES.md", "ROADMAP.md"}
SKIP_DIRS = {".git", ".claude", ".pytest_cache", "node_modules",
             "__pycache__"}

# stdlib / third-party -m targets that are not repo files
EXTERNAL_MODULES = {"pytest", "pip", "venv", "http.server"}

# flags argparse provides on every parser
IMPLICIT_FLAGS = {"--help"}

_INVOKE_RE = re.compile(r"python3?\s+-m\s+([A-Za-z_][\w.]*)")
_PYFILE_RE = re.compile(r"(?<![\w/])((?:[\w.-]+/)*[\w.-]+\.py)\b")
# a documented long flag; (?<![\w=-]) drops ENV=--flag forms and
# mid-word dashes, \b won't cut "--freed-mode" short thanks to [\w-]*
_FLAG_RE = re.compile(r"(?<![\w=\-])--[a-zA-Z][\w-]*")


def module_to_path(module: str) -> Path | None:
    """Map a ``-m`` target to the repo file that implements it."""
    rel = Path(*module.split("."))
    for cand in (REPO / rel.with_suffix(".py"),
                 REPO / rel / "__main__.py",
                 REPO / "src" / rel.with_suffix(".py"),
                 REPO / "src" / rel / "__main__.py"):
        if cand.is_file():
            return cand
    return None


def parser_flags(py_path: Path) -> set[str]:
    """All ``add_argument`` string literals in a module, via ast."""
    tree = ast.parse(py_path.read_text(), filename=str(py_path))
    flags: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    flags.add(arg.value)
    return flags


def referenced_modules(text: str) -> list[tuple[int, str, Path | None]]:
    """(line, name, mapped path) for every module/script the doc cites."""
    out = []
    for i, line in enumerate(text.splitlines(), 1):
        for m in _INVOKE_RE.finditer(line):
            name = m.group(1)
            if name in EXTERNAL_MODULES:
                continue
            out.append((i, name, module_to_path(name)))
        for m in _PYFILE_RE.finditer(line):
            p = REPO / m.group(1)
            if p.is_file():
                out.append((i, m.group(1), p))
    return out


def check_file(md: Path) -> list[str]:
    text = md.read_text()
    try:
        rel = md.relative_to(REPO)
    except ValueError:          # e.g. a tempfile in the negative test
        rel = md.name
    errors: list[str] = []

    refs = referenced_modules(text)
    for line, name, path in refs:
        if path is None:
            errors.append(f"{rel}:{line}: `{name}` is documented but no "
                          "such module/script exists in the repo")
    known = IMPLICIT_FLAGS.union(
        *(parser_flags(p) for _, _, p in refs if p is not None))

    for i, line in enumerate(text.splitlines(), 1):
        for m in _FLAG_RE.finditer(line):
            flag = m.group(0)
            if flag in known:
                continue
            if not any(p is not None for _, _, p in refs):
                continue  # doc cites no local CLI: nothing to check against
            errors.append(
                f"{rel}:{i}: documented flag `{flag}` not found in any "
                "parser of the modules this doc references "
                f"({', '.join(sorted({n for _, n, p in refs if p}))})")
    return errors


def find_docs(root: Path = REPO) -> list[Path]:
    return sorted(
        p for p in root.rglob("*.md")
        if p.name not in SKIP_NAMES
        and not (SKIP_DIRS & set(part.name for part in p.parents)))


def main(paths: list[Path] | None = None) -> int:
    docs = paths if paths is not None else find_docs()
    errors: list[str] = []
    for md in docs:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        print(f"docs check: {len(docs)} file(s) clean")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main([Path(a).resolve() for a in sys.argv[1:]] or None))
