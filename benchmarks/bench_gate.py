"""Bench-trajectory regression gate for the xsim throughput matrix and
the ASA serving benchmark.

Collects the per-leg ``xsim_throughput_*.json`` records the CI matrix
uploads (ref / interpret / sharded / traced), merges them into one
``BENCH_xsim.json`` artifact — the per-commit point of the throughput
trajectory — and FAILS (exit 1) when the ref-mode single-device
scenarios/sec drops more than ``--tolerance`` (default 25%) below the
committed baseline in ``benchmarks/baselines/xsim_throughput.json``, or
when its us_per_scenario exceeds the mirrored ceiling (baseline ÷ (1 −
tolerance) — the two fields are reciprocal, so both checks trip at the
same throughput).

``serve_latency*.json`` legs (benchmarks/serve_latency.py) are gated the
same way against ``benchmarks/baselines/serve_latency.json``, keyed by
load mode: the open-loop leg (``serve``) gates ``decisions_per_sec``
(floor baseline × (1 − tolerance)) — the sustained rate under a
saturating backlog; the closed-loop leg (``serve-closed``) gates the
``p50_ms``/``p99_ms`` *service-time* percentiles (ceilings baseline ÷
(1 − tolerance)) measured at fixed in-flight concurrency. Unlike the
reciprocal throughput pair, rate and tail latency CAN regress
independently (a stall lengthens the tail without moving the mean rate
much), so both serve gates add signal. Batching health is gated too:
``pad_fraction``/``defer_rate`` must stay under the **absolute**
ceilings (``*_max``) the baseline carries. The serving bench must also
upload its ``serve_metrics`` registry-snapshot record
(``--metrics-json``) — a missing serve_metrics leg fails the gate.
``serve_chaos*.json`` (benchmarks/serve_chaos.py, the fault-injection
soak) is gated against ``benchmarks/baselines/serve_chaos.json``:
``hung_futures`` must not exceed the absolute ceiling (committed as 0 —
the zero-hung-futures invariant), ``recovery_p99_ms`` (fault → next
successful resolve) stays under baseline ÷ (1 − tolerance), and
``shed_rate`` under the absolute ``shed_rate_max``.  Pass ``--no-serve``
to skip serve gating when replaying old throughput-only artifact sets.

Legs are schema-v1 ``repro.obs.telemetry`` records (the only format the
runners emit since the observability PR): the gated numbers live in the
``profile`` section, fleet counters in ``metrics``, ring accounting in
``trace``. A leg that fails schema validation — a missing ``profile``
section above all — is a NAMED failure, not a KeyError and not a silent
skip. Pre-telemetry flat records are still merged (old artifacts
replayed through the gate) but new leg files must validate.

Only the ref-mode vmap leg is gated: the interpret leg measures the
Pallas kernel under the (slow, deliberately unoptimized) interpreter,
the sharded leg splits one CI core across 8 fake devices, and the traced
leg pays the ring-append overhead — all trajectory signals, not
regression gates.

Pure stdlib on purpose: the CI gate job runs it straight from a
checkout, no jax install (``repro.obs.telemetry`` is stdlib-only and
imported via the repo's ``src/`` path).

  python -m benchmarks.bench_gate --bench-dir bench-artifacts \
      --out BENCH_xsim.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# bench_gate runs from a bare checkout (no pip install): reach the
# stdlib-only repro.obs.telemetry through the source tree directly
_SRC = Path(__file__).resolve().parent.parent / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.obs import telemetry  # noqa: E402  (needs the path shim)

BASELINE_DEFAULT = Path(__file__).resolve().parent / "baselines" \
    / "xsim_throughput.json"
SERVE_BASELINE_DEFAULT = Path(__file__).resolve().parent / "baselines" \
    / "serve_latency.json"
CHAOS_BASELINE_DEFAULT = Path(__file__).resolve().parent / "baselines" \
    / "serve_chaos.json"


def leg_key(leg: dict) -> str:
    """Stable merge key: freed_mode, plus shard count / traced markers."""
    shards = int(leg.get("n_shards", 1) or 1)
    mode = leg.get("freed_mode", "unknown")
    key = mode if shards == 1 else f"{mode}-shards{shards}"
    return f"{key}-traced" if leg.get("traced") else key


def _leg_view(rec: dict) -> dict:
    """Normalize one record into the flat leg view the gate consumes.

    Telemetry records are validated and flattened
    (``telemetry.throughput_leg``); a schema violation raises ValueError
    naming the problem — the caller turns it into a per-leg failure.
    Pre-telemetry flat records pass through as-is.
    """
    if telemetry.is_telemetry(rec):
        leg = telemetry.throughput_leg(rec)
        leg["profile"] = rec["profile"]
        leg["metrics"] = rec.get("metrics")
        leg["trace"] = rec.get("trace")
        for k in ("n_scenarios", "backend"):
            if k in rec["run"]:
                leg[k] = rec["run"][k]
        return leg
    if "scenarios_per_sec" not in rec:
        raise ValueError("neither a telemetry record (telemetry_version) "
                         "nor a flat record with scenarios_per_sec")
    return rec


def collect_legs(bench_dir: Path) -> tuple[dict[str, dict], list[str]]:
    """(legs, failures): merged leg views + per-file schema failures."""
    legs: dict[str, dict] = {}
    failures: list[str] = []
    for path in sorted(bench_dir.rglob("xsim_throughput*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"leg file {path} is unreadable: {e}")
            continue
        try:
            leg = _leg_view(rec)
        except ValueError as e:
            # name the broken leg precisely — a matrix leg must never
            # fail the schema silently (nor as a KeyError downstream)
            run = rec.get("run", {}) if isinstance(rec, dict) else {}
            label = run.get("label") or rec.get("label") or path.name
            key = leg_key({**rec, **run}) if isinstance(rec, dict) else "?"
            failures.append(f"leg {key!r} ({label}, {path}) failed "
                            f"telemetry validation: {e}")
            continue
        legs[leg_key(leg)] = leg
    return legs, failures


def serve_leg_key(leg: dict) -> str:
    """Stable merge key for serving legs: the load mode (open-loop legs
    stay keyed ``serve`` for baseline continuity; closed-loop legs get
    ``serve-closed``) plus the shard count.  The smoke and full replays
    of one mode share a key on purpose — one compiled shape, one gate;
    the label disambiguates in the merged artifact, not in the gate."""
    shards = int(leg.get("n_shards", 1) or 1)
    key = "serve" if leg.get("mode", "open") == "open" else "serve-closed"
    return key if shards == 1 else f"{key}-shards{shards}"


def collect_serve_legs(bench_dir: Path) -> tuple[dict[str, dict],
                                                 list[str]]:
    """(legs, failures) for serve_latency*.json — same contract as
    ``collect_legs``: schema violations are named failures, never
    silent skips."""
    legs: dict[str, dict] = {}
    failures: list[str] = []
    for path in sorted(bench_dir.rglob("serve_latency*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"serve leg file {path} is unreadable: {e}")
            continue
        try:
            leg = telemetry.serve_leg(rec)
        except ValueError as e:
            run = rec.get("run", {}) if isinstance(rec, dict) else {}
            label = run.get("label") or path.name
            failures.append(f"serve leg ({label}, {path}) failed "
                            f"telemetry validation: {e}")
            continue
        leg["profile"] = rec["profile"]
        leg["metrics"] = rec.get("metrics")
        legs[serve_leg_key(leg)] = leg
    return legs, failures


def collect_serve_metrics_legs(bench_dir: Path) -> tuple[dict[str, dict],
                                                         list[str]]:
    """(legs, failures) for serve_metrics*.json — the serving loop's
    registry snapshot the bench emits via ``--metrics-json``.  Keyed by
    shard count; schema violations are named failures."""
    legs: dict[str, dict] = {}
    failures: list[str] = []
    for path in sorted(bench_dir.rglob("serve_metrics*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"serve_metrics file {path} is unreadable: "
                            f"{e}")
            continue
        try:
            leg = telemetry.serve_metrics_leg(rec)
        except ValueError as e:
            run = rec.get("run", {}) if isinstance(rec, dict) else {}
            label = run.get("label") or path.name
            failures.append(f"serve_metrics leg ({label}, {path}) failed "
                            f"telemetry validation: {e}")
            continue
        shards = int(leg.get("n_shards", 1) or 1)
        key = "serve-metrics" if shards == 1 \
            else f"serve-metrics-shards{shards}"
        legs[key] = leg
    return legs, failures


def collect_serve_chaos_legs(bench_dir: Path) -> tuple[dict[str, dict],
                                                       list[str]]:
    """(legs, failures) for serve_chaos*.json — the chaos soak record
    (benchmarks/serve_chaos.py --json).  One leg keyed ``serve-chaos``;
    schema violations are named failures."""
    legs: dict[str, dict] = {}
    failures: list[str] = []
    for path in sorted(bench_dir.rglob("serve_chaos*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            failures.append(f"serve_chaos file {path} is unreadable: {e}")
            continue
        try:
            leg = telemetry.serve_chaos_leg(rec)
        except ValueError as e:
            run = rec.get("run", {}) if isinstance(rec, dict) else {}
            label = run.get("label") or path.name
            failures.append(f"serve_chaos leg ({label}, {path}) failed "
                            f"telemetry validation: {e}")
            continue
        legs["serve-chaos"] = leg
    return legs, failures


def gate_serve_chaos(legs: dict[str, dict], baseline: dict,
                     tolerance: float) -> tuple[dict, list[str]]:
    """Chaos-soak gate — the three robustness invariants:

    * ``hung_futures`` must be exactly the absolute baseline ceiling's
      worth — which the committed baseline pins at **0** (no tolerance
      scaling: one future that never resolves is a correctness bug, not
      a perf regression);
    * ``recovery_p99_ms`` (fault injection → next successful resolve)
      stays under the ceiling baseline ÷ (1 − tolerance) — this covers
      the crash → supervised-restore-and-restart tail;
    * ``shed_rate`` stays under the **absolute** ``shed_rate_max``
      ceiling (a ratio, like the batching-health caps in
      :func:`gate_serve` — ratio-scaling a ratio gates nothing).

    Missing legs / baseline-gated metrics are failures, as everywhere."""
    failures: list[str] = []
    checks: dict[str, dict] = {}
    for key, base in baseline["legs"].items():
        rec = legs.get(key)
        if rec is None:
            failures.append(f"gated chaos leg {key!r} missing from the "
                            f"merged bench set (have: {sorted(legs)})")
            continue
        checks[key] = {"ok": True}
        if "recovery_p99_ms" in base:
            if "recovery_p99_ms" not in rec:
                failures.append(f"{key}: record carries no "
                                "recovery_p99_ms but the baseline "
                                "gates it")
                checks[key]["ok"] = False
            else:
                ceil = base["recovery_p99_ms"] / (1.0 - tolerance)
                val = float(rec["recovery_p99_ms"])
                ok = val <= ceil
                checks[key].update(recovery_p99_ms=val,
                                   recovery_baseline=base["recovery_p99_ms"],
                                   recovery_ceiling=ceil, recovery_ok=ok)
                checks[key]["ok"] &= ok
                if not ok:
                    failures.append(
                        f"{key}: fault-recovery p99 {val:.0f} ms is above "
                        f"the ceiling {ceil:.0f} (baseline "
                        f"{base['recovery_p99_ms']:.0f} ÷ (1 − "
                        f"{tolerance:.0%})) — restarts or containment "
                        f"are digging out too slowly")
        for metric, cap_key in (("hung_futures", "hung_futures_max"),
                                ("shed_rate", "shed_rate_max")):
            if cap_key not in base:
                continue
            if metric not in rec:
                failures.append(f"{key}: record carries no {metric} but "
                                f"the baseline gates it")
                checks[key]["ok"] = False
                continue
            cap = float(base[cap_key])
            val = float(rec[metric])
            ok = val <= cap
            checks[key].update(**{metric: val, cap_key: cap,
                                  f"{metric}_ok": ok})
            checks[key]["ok"] &= ok
            if not ok:
                msg = (f"{key}: {metric} {val:.3f} is above the absolute "
                       f"ceiling {cap:.3f}")
                if metric == "hung_futures":
                    msg += (" — a submitted future never resolved under "
                            "chaos; this is the zero-hung-futures "
                            "invariant, not a perf floor")
                failures.append(msg)
    return {"tolerance": tolerance, "checks": checks,
            "ok": not failures}, failures


def gate_serve(legs: dict[str, dict], baseline: dict,
               tolerance: float) -> tuple[dict, list[str]]:
    """Serve-side gate: for every baseline leg, ``decisions_per_sec``
    must hold the floor baseline × (1 − tolerance), latency percentiles
    (``p50_ms``/``p99_ms``) must stay under their ceilings baseline ÷
    (1 − tolerance), and the batching-health rates must stay under the
    **absolute** ceilings ``pad_fraction_max``/``defer_rate_max`` when
    the baseline carries them (absolute on purpose: a pad fraction is
    already a ratio, and closed-loop legs sit at a structural level set
    by concurrency/batch_size — ratio-scaling a ratio gates nothing).
    Missing gated legs and baseline-gated metrics missing from a record
    are failures, exactly as in :func:`gate`."""
    failures: list[str] = []
    checks: dict[str, dict] = {}
    for key, base in baseline["legs"].items():
        rec = legs.get(key)
        if rec is None:
            failures.append(f"gated serve leg {key!r} missing from the "
                            f"merged bench set (have: {sorted(legs)})")
            continue
        checks[key] = {"ok": True}
        if "decisions_per_sec" in base:
            floor = base["decisions_per_sec"] * (1.0 - tolerance)
            dps = float(rec["decisions_per_sec"])
            ok = dps >= floor
            checks[key].update(decisions_per_sec=dps,
                               dps_baseline=base["decisions_per_sec"],
                               dps_floor=floor, dps_ok=ok)
            checks[key]["ok"] &= ok
            if not ok:
                failures.append(
                    f"{key}: {dps:.0f} decisions/sec is below the "
                    f"regression floor {floor:.0f} (baseline "
                    f"{base['decisions_per_sec']:.0f} − {tolerance:.0%})")
        for pct in ("p50_ms", "p99_ms"):
            if pct not in base:
                continue
            if pct not in rec:
                failures.append(f"{key}: record carries no {pct} but "
                                f"the baseline gates it")
                checks[key]["ok"] = False
                continue
            ceil = base[pct] / (1.0 - tolerance)
            val = float(rec[pct])
            ok = val <= ceil
            checks[key].update(**{pct: val, f"{pct}_baseline": base[pct],
                                  f"{pct}_ceiling": ceil,
                                  f"{pct}_ok": ok})
            checks[key]["ok"] &= ok
            if not ok:
                failures.append(
                    f"{key}: {pct[:3]} decision latency {val:.0f} ms is "
                    f"above the regression ceiling {ceil:.0f} (baseline "
                    f"{base[pct]:.0f} ÷ (1 − {tolerance:.0%}))")
        for rate, cap_key in (("pad_fraction", "pad_fraction_max"),
                              ("defer_rate", "defer_rate_max")):
            if cap_key not in base:
                continue
            if rate not in rec:
                failures.append(f"{key}: record carries no {rate} but "
                                f"the baseline gates it")
                checks[key]["ok"] = False
                continue
            cap = float(base[cap_key])
            val = float(rec[rate])
            ok = val <= cap
            checks[key].update(**{rate: val, cap_key: cap,
                                  f"{rate}_ok": ok})
            checks[key]["ok"] &= ok
            if not ok:
                failures.append(
                    f"{key}: {rate} {val:.3f} is above the absolute "
                    f"ceiling {cap:.3f} — the batcher is padding or "
                    f"deferring more than the committed baseline allows")
    return {"tolerance": tolerance, "checks": checks,
            "ok": not failures}, failures


def gate(legs: dict[str, dict], baseline: dict,
         tolerance: float) -> tuple[dict, list[str]]:
    """Returns (gate record, failure messages). Gated legs = baseline keys
    present in the merged set; a missing gated leg is itself a failure
    (a silently dropped matrix leg must not pass the gate). Both sides of
    the throughput record are gated when the baseline carries them:
    ``scenarios_per_sec`` may not drop more than ``tolerance`` below the
    baseline, and ``us_per_scenario`` (the per-scenario latency) may not
    exceed the mirrored ceiling baseline ÷ (1 − tolerance); a
    baseline-gated metric missing from the record is a failure."""
    failures: list[str] = []
    checks: dict[str, dict] = {}
    for key, base in baseline["legs"].items():
        floor = base["scenarios_per_sec"] * (1.0 - tolerance)
        rec = legs.get(key)
        if rec is None:
            failures.append(f"gated leg {key!r} missing from the merged "
                            f"bench set (have: {sorted(legs)})")
            continue
        sps = float(rec["scenarios_per_sec"])
        ok = sps >= floor
        checks[key] = {
            "scenarios_per_sec": sps,
            "baseline": base["scenarios_per_sec"],
            "floor": floor,
            "ok": ok,
        }
        if not ok:
            failures.append(
                f"{key}: {sps:.0f} scenarios/sec is below the regression "
                f"floor {floor:.0f} (baseline {base['scenarios_per_sec']:.0f}"
                f" − {tolerance:.0%})")
        if "us_per_scenario" in base:
            if "us_per_scenario" not in rec:
                # same philosophy as a missing leg: a baseline-gated
                # metric silently vanishing from the record must not pass
                failures.append(
                    f"{key}: record carries no us_per_scenario but the "
                    f"baseline gates it")
                checks[key]["ok"] = False
                continue
            # ceiling = baseline / (1 − tolerance): the exact mirror of
            # the scen/s floor (the two fields are reciprocal), so both
            # checks trip at the same throughput and the us gate only
            # adds signal if a future bench derives the fields
            # independently
            ceil = base["us_per_scenario"] / (1.0 - tolerance)
            us = float(rec["us_per_scenario"])
            us_ok = us <= ceil
            checks[key].update(us_per_scenario=us,
                               us_baseline=base["us_per_scenario"],
                               us_ceiling=ceil, us_ok=us_ok)
            checks[key]["ok"] = ok and us_ok
            if not us_ok:
                failures.append(
                    f"{key}: {us:.0f} us/scenario is above the regression "
                    f"ceiling {ceil:.0f} (baseline "
                    f"{base['us_per_scenario']:.0f} ÷ (1 − {tolerance:.0%}))")
    return {"tolerance": tolerance, "checks": checks,
            "ok": not failures}, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", type=Path, required=True,
                    help="directory holding the downloaded matrix-leg "
                         "JSONs (searched recursively)")
    ap.add_argument("--baseline", type=Path, default=BASELINE_DEFAULT,
                    help="committed baseline record (default: "
                         "benchmarks/baselines/xsim_throughput.json)")
    ap.add_argument("--serve-baseline", type=Path,
                    default=SERVE_BASELINE_DEFAULT,
                    help="committed serving baseline (default: "
                         "benchmarks/baselines/serve_latency.json)")
    ap.add_argument("--chaos-baseline", type=Path,
                    default=CHAOS_BASELINE_DEFAULT,
                    help="committed chaos-soak baseline (default: "
                         "benchmarks/baselines/serve_chaos.json)")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serve_latency and serve_chaos gates "
                         "(replaying throughput-only artifact sets)")
    ap.add_argument("--out", type=Path, default=Path("BENCH_xsim.json"),
                    help="merged bench-trajectory artifact to write")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop below baseline "
                         "(default 0.25)")
    args = ap.parse_args()

    baseline = json.loads(args.baseline.read_text())
    legs, schema_failures = collect_legs(args.bench_dir)
    if not legs and not schema_failures:
        print(f"bench_gate: no xsim_throughput*.json under "
              f"{args.bench_dir}", file=sys.stderr)
        return 1
    gate_rec, failures = gate(legs, baseline, args.tolerance)
    failures = schema_failures + failures

    serve_legs: dict[str, dict] = {}
    serve_metrics_legs: dict[str, dict] = {}
    serve_chaos_legs: dict[str, dict] = {}
    serve_baseline = None
    serve_gate_rec = None
    chaos_baseline = None
    chaos_gate_rec = None
    if not args.no_serve:
        serve_baseline = json.loads(args.serve_baseline.read_text())
        serve_legs, serve_schema_failures = collect_serve_legs(
            args.bench_dir)
        serve_metrics_legs, metrics_failures = \
            collect_serve_metrics_legs(args.bench_dir)
        if not serve_metrics_legs:
            # the registry snapshot is part of the gated contract: a
            # serve-bench run that stops uploading it must not pass
            metrics_failures.append(
                "no serve_metrics*.json in the artifact set: the "
                "serving bench must upload its registry-snapshot record "
                "(serve_latency.py --metrics-json)")
        serve_gate_rec, serve_failures = gate_serve(
            serve_legs, serve_baseline, args.tolerance)
        serve_failures = serve_schema_failures + metrics_failures \
            + serve_failures
        failures += serve_failures
        serve_gate_rec["ok"] = not serve_failures

        chaos_baseline = json.loads(args.chaos_baseline.read_text())
        serve_chaos_legs, chaos_schema_failures = \
            collect_serve_chaos_legs(args.bench_dir)
        chaos_gate_rec, chaos_failures = gate_serve_chaos(
            serve_chaos_legs, chaos_baseline, args.tolerance)
        chaos_failures = chaos_schema_failures + chaos_failures
        failures += chaos_failures
        chaos_gate_rec["ok"] = not chaos_failures
    gate_rec["ok"] = not failures

    merged = {"legs": legs, "baseline": baseline, "gate": gate_rec,
              "serve_legs": serve_legs,
              "serve_metrics_legs": serve_metrics_legs,
              "serve_baseline": serve_baseline,
              "serve_gate": serve_gate_rec,
              "serve_chaos_legs": serve_chaos_legs,
              "serve_chaos_baseline": chaos_baseline,
              "serve_chaos_gate": chaos_gate_rec}
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(merged, indent=2))

    for key in sorted(legs):
        rec = legs[key]
        print(f"bench_gate/{key}: {rec['scenarios_per_sec']:.0f} "
              f"scenarios/sec (n={rec.get('n_scenarios')}, "
              f"shards={rec.get('n_shards', 1)}, "
              f"backend={rec.get('backend')})")
        prof = rec.get("profile")
        if prof:
            # budget-bound → event-bound trajectory signal (see
            # xsim_throughput telemetry): steps the engine actually ran
            # vs the static n_steps budget, and the chunked-drain shape
            print(f"bench_gate/{key}/profile: "
                  f"steps {prof.get('steps_executed_max')} max / "
                  f"{prof.get('steps_executed_mean', 0):.1f} mean "
                  f"of {prof.get('steps_budget')} budget, "
                  f"chunks {prof.get('chunks_run')}×"
                  f"{prof.get('chunk_steps')}, "
                  f"drained {prof.get('drained_frac', 0):.3f}, "
                  f"compile {prof.get('compile_s', 0):.1f}s / steady "
                  f"{prof.get('steady_s', 0):.2f}s")
            if "trace_overhead_frac" in prof:
                tr = rec.get("trace") or {}
                print(f"bench_gate/{key}/trace: "
                      f"overhead {prof['trace_overhead_frac']:+.1%}, "
                      f"events {tr.get('events_total')} "
                      f"(dropped {tr.get('events_dropped', 0)}, "
                      f"capacity {tr.get('capacity')}/scenario)")
    for key in sorted(serve_legs):
        rec = serve_legs[key]
        print(f"bench_gate/{key}: "
              f"{rec.get('decisions_per_sec', 0):.0f} decisions/sec, "
              f"p50 {rec.get('p50_ms', 0):.1f} ms / "
              f"p99 {rec.get('p99_ms', 0):.1f} ms "
              f"(mode={rec.get('mode', 'open')}, "
              f"tenants={rec.get('n_tenants')}, "
              f"batch={rec.get('batch_size')}, "
              f"shards={rec.get('n_shards', 1)}, "
              f"backend={rec.get('backend')})")
        if "pad_fraction" in rec or "defer_rate" in rec:
            print(f"bench_gate/{key}/batching: "
                  f"pad_fraction {rec.get('pad_fraction', 0):.3f}, "
                  f"defer_rate {rec.get('defer_rate', 0):.3f}")
    for key in sorted(serve_metrics_legs):
        rec = serve_metrics_legs[key]
        print(f"bench_gate/{key}: "
              f"obs overhead {rec.get('serve_obs_overhead_frac', 0):+.1%}"
              f" decisions/sec, pad_fraction "
              f"{rec.get('pad_fraction', 0):.3f}, defer_rate "
              f"{rec.get('defer_rate', 0):.3f} "
              f"(requests={rec.get('asa_serve_requests_total')}, "
              f"deferrals={rec.get('asa_serve_deferrals_total')}, "
              f"evictions={rec.get('asa_serve_evictions_total')})")
    for key in sorted(serve_chaos_legs):
        rec = serve_chaos_legs[key]
        faults = rec.get("faults_fired") or {}
        print(f"bench_gate/{key}: recovery p99 "
              f"{rec.get('recovery_p99_ms', 0):.0f} ms, "
              f"hung={rec.get('hung_futures')}, "
              f"shed_rate={rec.get('shed_rate', 0):.3f}, "
              f"restarts={rec.get('restarts')}, "
              f"faults={sum(faults.values())} "
              f"(crashes={rec.get('asa_serve_crashes_total')}, "
              f"step_errors={rec.get('asa_serve_step_errors_total')}, "
              f"lease_evictions="
              f"{rec.get('asa_serve_lease_evictions_total')})")
    if failures:
        for f in failures:
            print(f"bench_gate: FAIL {f}", file=sys.stderr)
        return 1
    print(f"bench_gate: ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
