"""Bench-trajectory regression gate for the xsim throughput matrix.

Collects the per-leg ``xsim_throughput_*.json`` records the CI matrix
uploads (ref / interpret / sharded), merges them into one
``BENCH_xsim.json`` artifact — the per-commit point of the throughput
trajectory — and FAILS (exit 1) when the ref-mode single-device
scenarios/sec drops more than ``--tolerance`` (default 25%) below the
committed baseline in ``benchmarks/baselines/xsim_throughput.json``.

Only the ref-mode vmap leg is gated: the interpret leg measures the
Pallas kernel under the (slow, deliberately unoptimized) interpreter,
and the sharded leg splits one CI core across 8 fake devices — both are
trajectory signals, not regression gates.

Pure stdlib on purpose: the CI gate job runs it straight from a
checkout, no jax install.

  python -m benchmarks.bench_gate --bench-dir bench-artifacts \
      --out BENCH_xsim.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_DEFAULT = Path(__file__).resolve().parent / "baselines" \
    / "xsim_throughput.json"


def leg_key(rec: dict) -> str:
    """Stable merge key: freed_mode, plus the shard count when sharded."""
    shards = int(rec.get("n_shards", 1) or 1)
    mode = rec.get("freed_mode", "unknown")
    return mode if shards == 1 else f"{mode}-shards{shards}"


def collect_legs(bench_dir: Path) -> dict[str, dict]:
    legs: dict[str, dict] = {}
    for path in sorted(bench_dir.rglob("xsim_throughput*.json")):
        try:
            rec = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_gate: skipping unreadable {path}: {e}",
                  file=sys.stderr)
            continue
        if "scenarios_per_sec" not in rec:
            print(f"bench_gate: skipping {path}: no scenarios_per_sec",
                  file=sys.stderr)
            continue
        legs[leg_key(rec)] = rec
    return legs


def gate(legs: dict[str, dict], baseline: dict,
         tolerance: float) -> tuple[dict, list[str]]:
    """Returns (gate record, failure messages). Gated legs = baseline keys
    present in the merged set; a missing gated leg is itself a failure
    (a silently dropped matrix leg must not pass the gate)."""
    failures: list[str] = []
    checks: dict[str, dict] = {}
    for key, base in baseline["legs"].items():
        floor = base["scenarios_per_sec"] * (1.0 - tolerance)
        rec = legs.get(key)
        if rec is None:
            failures.append(f"gated leg {key!r} missing from the merged "
                            f"bench set (have: {sorted(legs)})")
            continue
        sps = float(rec["scenarios_per_sec"])
        ok = sps >= floor
        checks[key] = {
            "scenarios_per_sec": sps,
            "baseline": base["scenarios_per_sec"],
            "floor": floor,
            "ok": ok,
        }
        if not ok:
            failures.append(
                f"{key}: {sps:.0f} scenarios/sec is below the regression "
                f"floor {floor:.0f} (baseline {base['scenarios_per_sec']:.0f}"
                f" − {tolerance:.0%})")
    return {"tolerance": tolerance, "checks": checks,
            "ok": not failures}, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", type=Path, required=True,
                    help="directory holding the downloaded matrix-leg "
                         "JSONs (searched recursively)")
    ap.add_argument("--baseline", type=Path, default=BASELINE_DEFAULT,
                    help="committed baseline record (default: "
                         "benchmarks/baselines/xsim_throughput.json)")
    ap.add_argument("--out", type=Path, default=Path("BENCH_xsim.json"),
                    help="merged bench-trajectory artifact to write")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop below baseline "
                         "(default 0.25)")
    args = ap.parse_args()

    baseline = json.loads(args.baseline.read_text())
    legs = collect_legs(args.bench_dir)
    if not legs:
        print(f"bench_gate: no xsim_throughput*.json under "
              f"{args.bench_dir}", file=sys.stderr)
        return 1
    gate_rec, failures = gate(legs, baseline, args.tolerance)

    merged = {"legs": legs, "baseline": baseline, "gate": gate_rec}
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(merged, indent=2))

    for key in sorted(legs):
        rec = legs[key]
        print(f"bench_gate/{key}: {rec['scenarios_per_sec']:.0f} "
              f"scenarios/sec (n={rec.get('n_scenarios')}, "
              f"shards={rec.get('n_shards', 1)}, "
              f"backend={rec.get('backend')})")
    if failures:
        for f in failures:
            print(f"bench_gate: FAIL {f}", file=sys.stderr)
        return 1
    print(f"bench_gate: ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
