"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The roofline benchmark
(benchmarks.roofline) runs as its own process (it needs 512 host devices
before jax init); this driver summarizes its JSON output if present.

``--engine event`` (default) drives the discrete-event QueueSim campaign;
``--engine xsim`` runs the same strategy comparison on the vectorized
fleet engine (repro.xsim) — thousands of scenarios in one jitted program.
``--policy`` (validated up front against ENGINE_POLICIES; see the
``--help`` epilog for the valid combinations) adds the §4.5 ASA-Naive
variant, the trained repro.rl learned head (both xsim-only) or the
pilot-job policy (both engines) to the sweep. ``--family`` (xsim only)
selects a robustness scenario family (``repro.xsim.families``).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

EXP = Path(__file__).resolve().parent.parent / "experiments"


def roofline_summary() -> None:
    rdir = EXP / "roofline"
    if not rdir.exists():
        print("roofline/none,0,run `python -m benchmarks.roofline` first")
        return
    for f in sorted(rdir.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "fail":
            print(f"roofline/{f.stem},0,FAIL={r['error'][:80]}")
            continue
        name = f"{r['arch']}__{r['shape']}"
        if r.get("opts"):
            name += "__" + "-".join(sorted(r["opts"]))
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline/{name},{r['analysis_s']*1e6:.0f},"
              f"dominant={r['dominant']};bound_ms={bound_s*1e3:.2f};"
              f"frac={r['roofline_fraction']:.3f};"
              f"useful={r['useful_ratio']:.2f}")


def dryrun_summary() -> None:
    ddir = EXP / "dryrun"
    if not ddir.exists():
        print("dryrun/none,0,run `python -m repro.launch.dryrun --all` first")
        return
    ok = fail = skip = 0
    for f in sorted(ddir.glob("*.json")):
        r = json.loads(f.read_text())
        s = r.get("status")
        ok += s == "ok"
        fail += s == "fail"
        skip += s == "skip"
    print(f"dryrun/all_cells,0,ok={ok};fail={fail};skip={skip}")


def xsim_main(n_seeds: int = 4, include_naive: bool = False,
              include_rl: bool = False, include_pilot: bool = False,
              family: str = "clean",
              n_shards: int | None = None,
              trace_path: Path | None = None,
              json_path: Path | None = None) -> None:
    """Strategy comparison on the batched engine + its throughput row.

    ``include_naive`` adds the §4.5 ASA-Naive (cancel/resubmit) policy to
    the sweep; its row carries the over-allocation OH the dependency-free
    variant pays for mispredictions. ``include_rl`` first trains the
    learned submission-policy head (the benchmarks.rl_train smoke recipe)
    and adds it to the sweep as policy id 4 (greedy actions).
    ``include_pilot`` adds the pilot-job policy (id 5): one peak-cores
    allocation queued once, stages cycled inside it.
    ``family`` picks the robustness scenario family
    (``repro.xsim.families``): "clean" (default, no capacity events),
    "faulty" (node failure + recovery), "elastic" (graceful
    drain/grow resizes) or "preempt" (preemptive shrinks).
    ``n_shards`` shard_maps the scenario axis over that many devices
    (validated against the inventory at the command line).
    ``trace_path`` runs the sweep with per-scenario event rings enabled
    and exports them as a Chrome trace (one track per scenario — this is
    the multi-policy trace the Perfetto acceptance check opens);
    ``json_path`` writes the ``xsim_strategies`` telemetry record.
    """
    import time

    import jax
    import numpy as np

    from repro.obs import export as obs_export
    from repro.obs import metrics as obs_metrics
    from repro.obs import telemetry
    from repro.xsim import policies
    from repro.xsim.families import family_grid
    from repro.xsim.grid import XSimConfig, run_grid, warm_fleet
    from repro.xsim.state import ASA, ASA_NAIVE, BIGJOB, PER_STAGE, PILOT, RL

    cfg = XSimConfig(n_warm=24, n_backlog=16, n_arrivals=24, max_stages=9,
                     t0=3600.0)
    if trace_path is not None:
        # the strategies sweep is a trajectory signal, not a gated bench:
        # tracing rides the one timed pass instead of paying a second one
        cfg = cfg.with_trace()
    policy_ids = (BIGJOB, PER_STAGE, ASA)
    if include_naive:
        policy_ids += (ASA_NAIVE,)
    if include_pilot:
        policy_ids += (PILOT,)
    params = None
    if include_rl:
        from benchmarks.rl_train import SMOKE
        from repro.rl import train as rl_train

        policy_ids += (RL,)
        # training rollouts dominate the wall-clock — shard them too
        params = rl_train.train(rl_train.TrainConfig(
            **SMOKE, n_shards=n_shards)).params
    grid = family_grid(cfg, family, n_seeds=n_seeds, shrink=1 / 64.0,
                       policy_ids=policy_ids)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet, grid, rounds=3, params=params,
                       n_shards=n_shards)
    t0 = time.time()
    final, m = run_grid(grid, fleet, pred_seed=7, params=params,
                        rl_mode="greedy", n_shards=n_shards)
    elapsed = time.time() - t0
    m = {k: np.asarray(v) for k, v in m.items()}

    by: dict[str, list[int]] = {}
    for i, lab in enumerate(grid.labels):
        by.setdefault(lab["strategy"], []).append(i)
    base = {k: min(float(np.mean(m[k][idx])) for idx in by.values())
            for k in ("twt_s", "makespan_s", "core_hours")}
    rows = {}
    for strat, idx in sorted(by.items()):
        tw = float(np.mean(m["twt_s"][idx]))
        mk = float(np.mean(m["makespan_s"][idx]))
        ch = float(np.mean(m["core_hours"][idx]))
        oh = float(np.mean(m["oh_hours"][idx]))
        rs = float(np.mean(m["restarts"][idx]))
        rows[strat] = {"twt_s": tw, "makespan_s": mk, "core_hours": ch,
                       "oh_hours": oh, "restarts": rs, "n": len(idx)}
        print(f"xsim_strategies/{strat},{elapsed * 1e6 / grid.n:.0f},"
              f"twt=+{(tw / max(base['twt_s'], 1e-9) - 1) * 100:.0f}%;"
              f"makespan=+{(mk / base['makespan_s'] - 1) * 100:.0f}%;"
              f"ch=+{(ch / base['core_hours'] - 1) * 100:.0f}%;"
              f"oh_hours={oh:.3f}")
    print(f"xsim_strategies/n,0,scenarios={grid.n};"
          f"scenarios_per_sec={grid.n / elapsed:.0f}")

    trace_sec = None
    if trace_path is not None:
        trace_path.parent.mkdir(parents=True, exist_ok=True)
        trace_sec = obs_export.write_chrome_trace(str(trace_path), final,
                                                  grid.labels)
        print(f"xsim_strategies/trace,0,"
              f"events={trace_sec['events_total']};"
              f"dropped={trace_sec['events_dropped']};"
              f"capacity={cfg.trace_capacity};wrote={trace_path}")
    if json_path is not None:
        summary = obs_metrics.sweep_summary(final,
                                            n_steps=grid.cfg.n_steps)
        rec = telemetry.record(
            "xsim_strategies",
            run={"label": "strategies", "n_shards": n_shards or 1,
                 "backend": jax.default_backend(),
                 "n_scenarios": grid.n, "n_steps": grid.cfg.n_steps,
                 "policies": sorted(by), "family": family,
                 "traced": trace_path is not None},
            profile={"sweep_s": elapsed,
                     "scenarios_per_sec": grid.n / elapsed,
                     "us_per_scenario": elapsed * 1e6 / grid.n},
            metrics={"fleet": obs_metrics.to_host(summary),
                     "strategies": rows},
            trace=trace_sec,
        )
        json_path.parent.mkdir(parents=True, exist_ok=True)
        json_path.write_text(json.dumps(rec, indent=2))


def main(include_pilot: bool = False) -> None:
    import time
    from collections import defaultdict

    from benchmarks import fig5_convergence, table2_accuracy
    from repro.sched.runner import run_table1, summarize_table1

    fig5_convergence.main()

    # table1 + fig9 share one simulation campaign (54 runs + naive)
    t0 = time.time()
    res = run_table1(seed=0, include_naive=True, include_pilot=include_pilot)
    elapsed = time.time() - t0
    summary = summarize_table1(res)
    n = len(res.runs)
    for strat, d in sorted(summary.items()):
        print(f"table1_strategies/{strat},{elapsed * 1e6 / max(n, 1):.0f},"
              f"twt=+{d['twt']*100:.0f}%;makespan=+{d['makespan']*100:.0f}%;"
              f"ch=+{d['ch']*100:.0f}%")
    print("table1_strategies/paper_ref,0,"
          "bigjob_ch=+53%;per_stage_makespan=+34%;asa_makespan=+2%")
    usage = defaultdict(float)
    for r in res.runs:
        usage[(r.workflow, r.strategy)] += r.core_hours
    for (wf, strat), ch in sorted(usage.items()):
        print(f"fig9_usage/{wf}_{strat},0,core_hours={ch:.1f}")

    table2_accuracy.main()
    dryrun_summary()
    roofline_summary()


# extra policies each engine understands; validated up front so a bad
# combination fails at the command line, not deep inside a jitted sweep
ENGINE_POLICIES = {
    "event": ("pilot",),
    "xsim": ("asa-naive", "rl", "pilot"),
}


def _policy_epilog() -> str:
    """Human-readable list of the valid --engine/--policy combinations."""
    lines = ["valid --engine / --policy combinations:"]
    for eng, ps in ENGINE_POLICIES.items():
        opts = ", ".join(f"--policy {p}" for p in ps) or "(no --policy)"
        lines.append(f"  --engine {eng}: {opts}")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        epilog=_policy_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--engine", choices=tuple(ENGINE_POLICIES),
                    default="event")
    ap.add_argument("--policy",
                    choices=sorted({p for ps in ENGINE_POLICIES.values()
                                    for p in ps}),
                    default=None,
                    help="asa-naive: include the §4.5 cancel/resubmit "
                         "variant in the xsim strategy sweep; rl: train "
                         "the repro.rl smoke recipe and include the "
                         "learned head (both xsim-only); pilot: include "
                         "the pilot-job policy (one peak-cores "
                         "allocation, stages cycled inside; both "
                         "engines)")
    ap.add_argument("--family", default="clean", metavar="NAME",
                    help="xsim only: robustness scenario family "
                         "(repro.xsim.families) — clean, faulty, "
                         "elastic or preempt")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="xsim only: shard_map the scenario axis over "
                         "the first N devices (default: single-device "
                         "vmap)")
    ap.add_argument("--trace", type=Path, default=None, metavar="OUT.json",
                    help="xsim only: record per-scenario event rings "
                         "during the sweep and export them as a Chrome "
                         "trace (open in Perfetto)")
    ap.add_argument("--no-trace", action="store_true",
                    help="explicitly disable tracing (the default; errors "
                         "if combined with --trace)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="xsim only: write the xsim_strategies telemetry "
                         "record as JSON")
    args = ap.parse_args()
    if args.policy is not None and args.policy not in \
            ENGINE_POLICIES[args.engine]:
        valid = " or ".join(
            f"--engine {e} --policy {p}"
            for e, ps in ENGINE_POLICIES.items() for p in ps) or "none"
        ap.error(
            f"--policy {args.policy} is not supported by --engine "
            f"{args.engine} (the event engine takes no --policy; valid "
            f"combinations: {valid})")
    if args.shards is not None:
        # validated up front, like --engine/--policy: a bad shard count
        # fails at the command line, not deep inside a shard_mapped sweep
        if args.engine != "xsim":
            ap.error(f"--shards requires --engine xsim (the {args.engine} "
                     "engine is not device-parallel)")
        from repro.launch.mesh import shards_arg_error
        err = shards_arg_error(args.shards)
        if err is not None:
            ap.error(err)
    if args.family != "clean":
        from repro.xsim.families import FAMILIES
        if args.family not in FAMILIES:
            ap.error(f"unknown --family {args.family} (choose from "
                     f"{', '.join(FAMILIES)})")
        if args.engine != "xsim":
            ap.error(f"--family requires --engine xsim (the {args.engine} "
                     "engine has no fault schedules)")
    # observability flags validate up front too, before any jit work
    if args.trace is not None and args.no_trace:
        ap.error("--trace and --no-trace are mutually exclusive")
    for flag, val in (("--trace", args.trace), ("--json", args.json)):
        if val is not None and args.engine != "xsim":
            ap.error(f"{flag} requires --engine xsim (the {args.engine} "
                     "engine carries no event rings)")
    if args.engine == "xsim":
        xsim_main(include_naive=args.policy == "asa-naive",
                  include_rl=args.policy == "rl",
                  include_pilot=args.policy == "pilot",
                  family=args.family,
                  n_shards=args.shards,
                  trace_path=args.trace, json_path=args.json)
    else:
        main(include_pilot=args.policy == "pilot")
