"""ASA-as-a-service latency benchmark: replay an xsim fleet as traffic.

xsim doubles as the load generator: one batched sweep simulates a fleet
of ASA-driven workflow streams, and the per-stage (submit, start, wait)
events of every scenario are replayed as live requests against
``repro.serve.loop.ASAServer``.  Each scenario is one tenant: its first
request asks the stage-0 submit-lead-time (a pure decision), then every
observed stage wait feeds the tenant's posterior (observe + decide in
one request).  The serve loop batches the stream through the jitted
decision core exactly as production traffic would.

Two load modes, both reported (telemetry schema v1, ``serve_latency``):

* **open-loop** (``run.mode = "open"``): the whole stream submits as
  fast as the queue takes it.  p99 here is queue-depth-dominated — it
  measures the backlog the server dug out of, not its service time —
  but decisions/sec under a saturating backlog is the honest sustained
  rate, so this leg stays gated on ``decisions_per_sec``.
* **closed-loop** (``run.mode = "closed"``, ``--closed-loop N``): a
  fixed number of requests stays in flight — each resolution admits the
  next submission, the way a fleet of N live workflow streams actually
  loads a server.  p50/p99 here measure *service time* (batch wait +
  jitted step + readback), the latency a tenant experiences at steady
  concurrency — these percentiles are the gated ones, alongside the
  batching-health rates (pad fraction, defer rate).

The same run also measures the **observability overhead**: after a
discarded warm-up pass, the open-loop replay runs paired spans-off /
spans-on passes over the same stream with the within-pair order
flipped every pair — balanced ordering cancels machine drift that
would otherwise bias whichever arm runs second — the collector is
parked during each timed pass (GC pauses are the dominant noise at
this rate), and the reported
``profile.serve_obs_overhead_frac`` is the ratio of the summed arm
walls: the relative decisions/sec cost of the full instrumentation
(registry + lifecycle spans), budget ≤ 5%.  Isolated, the recording
ops cost ~1 µs/request (~3-4% at smoke rates); the end-to-end A/B
additionally carries ~±10% session noise on a shared box, which the
per-pair ratios in ``profile.serve_obs_overhead_pairs`` make visible.
The instrumented arm is the one reported/gated, so the gate watches
the price tag too.

Also emitted: a ``serve_metrics`` record (``--metrics-json``) carrying
the raw ``obs.registry`` snapshot — pad fraction / defer rate /
eviction and deferral counters — which ``bench_gate`` requires, and a
merged Chrome trace (``--trace``) interleaving the serve-side request
lifecycle spans with device event rings from the load-generating sweep
(open it in Perfetto; the serve rows are wall-clock, the rings
sim-time).

The run ends with a **restart check**: the server state snapshots
through ``runtime.checkpoint``, a second server restores from it, and
every tenant's decision must be bitwise identical between the two — the
paper's estimator state survives a server restart exactly.  A mismatch
(or fewer than ``--min-tenants`` concurrent streams) exits non-zero.

  python -m benchmarks.serve_latency --smoke          # CI-sized replay
  python -m benchmarks.serve_latency                  # 3 replays
  python -m benchmarks.serve_latency --shards 8 --json bench/serve.json
"""

from __future__ import annotations

import argparse
import gc
import json
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.obs import telemetry
from repro.serve.loop import ASAServer, ServeConfig
from repro.xsim import policies
from repro.xsim.families import FAMILIES, family_grid
from repro.xsim.grid import XSimConfig, run_grid, stage_waits
from repro.xsim.state import ASA


def build_traffic(n_seeds: int, seed: int = 0, trace: bool = False,
                  family: str = "clean"):
    """Simulate a fleet and turn it into a request stream.

    Returns ``(events, n_tenants, final, labels)`` where ``events`` is a
    list of ``(t_sim, tenant, observed_wait_or_None)`` sorted by
    simulated event time — the order a live fleet would have produced
    them — and ``final``/``labels`` are the swept state (device event
    rings included when ``trace=True``) for the merged Chrome export.
    ``family`` picks the load generator's robustness scenario family
    (``repro.xsim.families``) — a faulty/elastic fleet produces the
    non-stationary wait mix a stressed center would stream at the
    service.
    """
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=3600.0)
    if trace:
        cfg = cfg.with_trace()
    grid = family_grid(cfg, family, policy_ids=(ASA,), n_seeds=n_seeds,
                       shrink=1 / 64.0, seed=seed)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    final, _ = run_grid(grid, fleet)
    waits, valid = stage_waits(final, cfg)
    sl = slice(cfg.max_jobs - cfg.max_stages, cfg.max_jobs)
    starts = np.asarray(final.start[:, sl])

    events: list[tuple[float, int, float | None]] = []
    for t in range(grid.n):
        # the stream opens with the stage-0 submit-lead query (pure
        # decision at the submission epoch) ...
        events.append((cfg.t0, t, None))
        # ... then every observed stage start feeds the posterior
        for y in range(cfg.max_stages):
            if valid[t, y]:
                events.append((float(starts[t, y]), t, float(waits[t, y])))
    events.sort(key=lambda e: (e[0], e[1]))
    return events, grid.n, final, grid.labels


def _percentiles(lat: list[float], n_requests: int, wall: float) -> dict:
    a = np.asarray(lat) * 1e3
    return {
        "n_requests": n_requests,
        "wall_s": wall,
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "mean_ms": float(a.mean()),
        "max_ms": float(a.max()),
        "decisions_per_sec": n_requests / wall,
    }


def _run_stream(server: ASAServer, events) -> tuple[float, list[float]]:
    """Submit the whole stream open-loop; returns (wall seconds,
    per-request submit→resolution latencies)."""
    lat: list[float] = []
    lat_lock = threading.Lock()

    def stamp(t_sub):
        def cb(fut):
            if fut.exception() is None:
                dt = time.perf_counter() - t_sub
                with lat_lock:
                    lat.append(dt)
        return cb

    futures = []
    t0 = time.perf_counter()
    for _t_sim, tenant, wait in events:
        fut = server.submit(tenant, wait)
        fut.add_done_callback(stamp(time.perf_counter()))
        futures.append(fut)
    for fut in futures:
        fut.result(timeout=300)
    return time.perf_counter() - t0, lat


def replay(server: ASAServer, events, replays: int) -> dict:
    """Open-loop replay: submit the stream as fast as the queue takes it,
    measure per-request latency (submit → future resolution) and the
    sustained decision rate."""
    wall = 0.0
    lat: list[float] = []
    for _rep in range(replays):
        w, ls = _run_stream(server, events)
        wall += w
        lat.extend(ls)
    return _percentiles(lat, replays * len(events), wall)


def replay_closed(server: ASAServer, events, concurrency: int) -> dict:
    """Closed-loop replay: keep exactly ``concurrency`` requests in
    flight — each resolution releases the next submission — so the
    measured p50/p99 is *service time* at fixed concurrency, not the
    queue depth the open-loop replay piles up."""
    lat: list[float] = []
    lat_lock = threading.Lock()
    slots = threading.BoundedSemaphore(concurrency)

    def stamp(t_sub):
        def cb(fut):
            if fut.exception() is None:
                dt = time.perf_counter() - t_sub
                with lat_lock:
                    lat.append(dt)
            slots.release()
        return cb

    futures = []
    t0 = time.perf_counter()
    for _t_sim, tenant, wait in events:
        slots.acquire()
        fut = server.submit(tenant, wait)
        fut.add_done_callback(stamp(time.perf_counter()))
        futures.append(fut)
    for fut in futures:
        fut.result(timeout=300)
    wall = time.perf_counter() - t0
    prof = _percentiles(lat, len(futures), wall)
    prof["concurrency"] = concurrency
    return prof


def restart_check(server: ASAServer, cfg: ServeConfig, tenants: int,
                  mesh=None) -> bool:
    """Snapshot → restore → every tenant's decision bitwise-identical.

    Runs both servers threaded (``stop()`` now rejects submissions into
    a dead loop, and a stopped server restarts cleanly).  The probes are
    decide-only — pure table reads — so batch composition can differ
    between the two loops without touching bitwise equality."""
    server.save(step=999)
    restored = ASAServer.restore(cfg, step=999, mesh=mesh)
    server.start()
    restored.start()
    ok = True
    try:
        fa = [server.submit(t) for t in range(tenants)]
        fb = [restored.submit(t) for t in range(tenants)]
        for a, b in zip(fa, fb):
            da, db = a.result(timeout=300), b.result(timeout=300)
            if (da.lead_s, da.expected_s, da.entropy) != \
                    (db.lead_s, db.expected_s, db.entropy):
                print(f"restart_check: tenant {da.tenant} diverged: "
                      f"{da} vs {db}")
                ok = False
    finally:
        server.stop()
        restored.stop()
    return ok


_RATE_COUNTERS = ("asa_serve_decisions_total",
                  "asa_serve_padded_rows_total",
                  "asa_serve_requests_total",
                  "asa_serve_deferrals_total",
                  "asa_serve_batches_total")


def _counter_delta(after: dict, before: dict, name: str) -> float:
    return float(after.get(name, 0)) - float(before.get(name, 0))


def _leg_rates(after: dict, before: dict) -> dict[str, float]:
    """pad_fraction/defer_rate over one replay leg (snapshot deltas)."""
    decisions = _counter_delta(after, before, "asa_serve_decisions_total")
    padded = _counter_delta(after, before, "asa_serve_padded_rows_total")
    requests = _counter_delta(after, before, "asa_serve_requests_total")
    deferrals = _counter_delta(after, before, "asa_serve_deferrals_total")
    batches = _counter_delta(after, before, "asa_serve_batches_total")
    dispatched = decisions + padded
    return {
        "pad_fraction": padded / dispatched if dispatched else 0.0,
        "defer_rate": deferrals / requests if requests else 0.0,
        "batches": int(batches),
        "batch_fill_mean": decisions / batches if batches else 0.0,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one replay of the 1026-tenant stream (CI job)")
    ap.add_argument("--replays", type=int, default=None,
                    help="stream replays (default: 1 smoke, 3 full)")
    ap.add_argument("--seeds", type=int, default=57, metavar="N",
                    help="xsim seeds per grid cell; 18 cells × N seeds "
                         "tenants (default 57 -> 1026 tenants)")
    ap.add_argument("--slots", type=int, default=1536,
                    help="tenant-table capacity (default 1536)")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard_map the query axis over the first N "
                         "devices (default: single-device vmap)")
    ap.add_argument("--family", choices=FAMILIES, default="clean",
                    help="load-generator robustness family "
                         "(repro.xsim.families): clean (default), "
                         "faulty, elastic or preempt")
    ap.add_argument("--closed-loop", type=int, default=64, metavar="K",
                    help="in-flight concurrency for the closed-loop leg "
                         "(0 disables the leg; default 64)")
    ap.add_argument("--min-tenants", type=int, default=1000,
                    help="fail unless at least this many concurrent "
                         "tenant streams were served (default 1000)")
    ap.add_argument("--ckpt-dir", type=Path, default=None,
                    help="checkpoint dir for the restart check (default: "
                         "a tmp dir)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the open-loop serve_latency record")
    ap.add_argument("--closed-json", type=Path, default=None,
                    metavar="PATH",
                    help="write the closed-loop serve_latency record")
    ap.add_argument("--metrics-json", type=Path, default=None,
                    metavar="PATH",
                    help="write the serve_metrics registry-snapshot "
                         "record (bench_gate requires it)")
    ap.add_argument("--trace", type=Path, default=None, metavar="PATH",
                    help="write the merged Chrome trace (serve lifecycle "
                         "spans + device event rings from the loadgen "
                         "sweep)")
    ap.add_argument("--trace-scenarios", type=int, default=8, metavar="K",
                    help="device rings to include in the merged trace "
                         "(first K scenarios; default 8 keeps the "
                         "artifact small)")
    args = ap.parse_args()
    if args.shards is not None:
        from repro.launch.mesh import shards_arg_error
        err = shards_arg_error(args.shards)
        if err is not None:
            ap.error(err)
        if args.batch_size % args.shards != 0:
            ap.error(f"--batch-size {args.batch_size} not divisible by "
                     f"--shards {args.shards}")
    if args.closed_loop < 0:
        ap.error(f"--closed-loop must be >= 0, got {args.closed_loop}")
    if args.trace_scenarios < 1:
        ap.error(f"--trace-scenarios must be >= 1, "
                 f"got {args.trace_scenarios}")
    replays = args.replays or (1 if args.smoke else 3)
    label = "smoke" if args.smoke else f"replay{replays}"

    t0 = time.perf_counter()
    events, n_tenants, lg_final, lg_labels = build_traffic(
        args.seeds, trace=args.trace is not None, family=args.family)
    loadgen_s = time.perf_counter() - t0
    n_obs = sum(1 for e in events if e[2] is not None)
    print(f"serve_latency/loadgen: {n_tenants} tenants, "
          f"{len(events)} events ({n_obs} observations) in {loadgen_s:.1f}s")
    if n_tenants > args.slots:
        ap.error(f"--slots {args.slots} < {n_tenants} tenants")

    ckpt_dir = args.ckpt_dir
    if ckpt_dir is None:
        import tempfile
        ckpt_dir = Path(tempfile.mkdtemp(prefix="serve_latency_ckpt_"))
    cfg = ServeConfig(n_slots=args.slots, batch_size=args.batch_size,
                      n_shards=args.shards,
                      checkpoint_dir=str(ckpt_dir))
    server = ASAServer(cfg)  # spans OFF: the uninstrumented reference

    # warm the compile cache outside the timed replay (one compiled shape
    # serves every batch)
    t0 = time.perf_counter()
    warm = server.submit(0)
    server.step_once(wait_s=0)
    warm.result(timeout=300)
    compile_s = time.perf_counter() - t0

    server.start()
    try:
        # discarded warm-up replay: the first pass over the stream pays
        # every one-time cost — tenant admissions and the per-shape
        # dispatch caches each distinct live-row count touches — which
        # would otherwise drown the A/B below (measured ~20x the
        # steady-state wall time); the gated legs measure steady state
        _run_stream(server, events)
        # instrumentation A/B: paired spans-off / spans-on replays of
        # the same stream, with the collector parked during each timed
        # pass (a gen-2 collection landing inside one arm of one pair is
        # the dominant noise source at this rate).  The WITHIN-pair arm
        # order flips every pair (off-on, on-off, off-on, ...): the
        # box's multi-second throughput regimes drift between arms, and
        # a fixed order biases whichever arm runs second.  The overhead
        # fraction is the ratio of the summed walls (aggregate, not the
        # median of per-pair ratios: pair ratios are heavy-tailed on a
        # shared box and their upper median biases high), and the
        # spans-on arm is the reported/gated open-loop leg — the gate
        # watches the instrumentation price tag too.  Isolated, the
        # recording ops cost ~1 µs/request (~3-4% at smoke rates); the
        # end-to-end A/B carries ±10% session noise on a shared CPU, so
        # read single-run figures with that bar in mind (the per-pair
        # ratios ride along in the record for exactly that check)
        ab_reps = max(6, replays + replays % 2)  # even: balanced orders
        wall_off = wall_on = 0.0
        overheads: list[float] = []
        lat_on: list[float] = []
        on_counts: dict[str, float] = {}
        for _rep in range(ab_reps):
            w_off = w_on = 0.0
            for spans in ((False, True) if _rep % 2 == 0
                          else (True, False)):
                server.obs.spans = spans
                if spans:
                    s0 = server.obs.registry.snapshot()
                gc.collect()
                gc.disable()
                w, ls = _run_stream(server, events)
                gc.enable()
                if spans:
                    s1 = server.obs.registry.snapshot()
                    w_on = w
                    lat_on.extend(ls)
                else:
                    w_off = w
            wall_off += w_off
            wall_on += w_on
            overheads.append((w_on - w_off) / w_off)
            for k in _RATE_COUNTERS:
                on_counts[k] = on_counts.get(k, 0.0) \
                    + float(s1[k]) - float(s0[k])
        prof = _percentiles(lat_on, ab_reps * len(events), wall_on)
        dps_off = ab_reps * len(events) / wall_off
        prof_closed = None
        if args.closed_loop:
            s2 = server.obs.registry.snapshot()
            gc.collect()
            gc.disable()
            prof_closed = replay_closed(server, events, args.closed_loop)
            gc.enable()
            s3 = server.obs.registry.snapshot()
    finally:
        server.stop()
    overhead_frac = wall_on / wall_off - 1.0
    prof["compile_s"] = compile_s
    prof["loadgen_s"] = loadgen_s
    prof["ab_replays"] = ab_reps
    prof["serve_obs_overhead_frac"] = overhead_frac
    prof["serve_obs_overhead_pairs"] = [round(o, 4) for o in overheads]
    prof["decisions_per_sec_uninstrumented"] = dps_off
    prof.update(_leg_rates(on_counts, {}))
    if prof_closed is not None:
        prof_closed["compile_s"] = compile_s
        prof_closed.update(_leg_rates(s3, s2))

    stats = server.stats
    sustained = stats["tenants"]
    ok_tenants = sustained >= args.min_tenants
    ok_restart = restart_check(server, cfg, n_tenants, mesh=server._mesh)

    shards = args.shards or 1
    run_common = {
        "n_tenants": sustained,
        "n_slots": args.slots,
        "batch_size": args.batch_size,
        "n_shards": shards,
        "n_devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "loadgen_seeds": args.seeds,
        "loadgen_family": args.family,
        "restart_bitwise": ok_restart,
    }
    print(f"serve_latency/{label}: p50={prof['p50_ms']:.2f}ms "
          f"p99={prof['p99_ms']:.2f}ms "
          f"decisions_per_sec={prof['decisions_per_sec']:.0f} "
          f"({prof['n_requests']} requests, {prof['batches']} batches, "
          f"fill={prof['batch_fill_mean']:.1f}/{args.batch_size}, "
          f"pad_frac={prof['pad_fraction']:.3f}, "
          f"defer_rate={prof['defer_rate']:.4f}, "
          f"obs_overhead={overhead_frac:+.1%}, "
          f"tenants={sustained}, shards={shards}, "
          f"backend={jax.default_backend()})")
    if prof_closed is not None:
        print(f"serve_latency/closed{args.closed_loop}: "
              f"p50={prof_closed['p50_ms']:.2f}ms "
              f"p99={prof_closed['p99_ms']:.2f}ms "
              f"decisions_per_sec={prof_closed['decisions_per_sec']:.0f} "
              f"({prof_closed['n_requests']} requests, "
              f"{prof_closed['batches']} batches, "
              f"fill={prof_closed['batch_fill_mean']:.1f}"
              f"/{args.batch_size}, "
              f"pad_frac={prof_closed['pad_fraction']:.3f})")
    print(f"serve_latency/{label}/checks: tenants>={args.min_tenants}: "
          f"{'ok' if ok_tenants else 'FAIL'}; restart bitwise: "
          f"{'ok' if ok_restart else 'FAIL'}")

    def write(path: Path | None, rec: dict) -> None:
        if path is not None:
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(rec, indent=2))

    rec = telemetry.record(
        "serve_latency",
        run={"label": label, "mode": "open", "replays": ab_reps,
             **run_common},
        profile=prof,
        metrics={
            "requests_total": prof["n_requests"],
            "observations_total": n_obs * ab_reps,
            "decisions_total": stats["decisions"],
            "deferred_end": stats["deferred"],
        },
        trace=None,
    )
    write(args.json, rec)
    if prof_closed is not None:
        write(args.closed_json, telemetry.record(
            "serve_latency",
            run={"label": f"closed{args.closed_loop}", "mode": "closed",
                 "concurrency": args.closed_loop, **run_common},
            profile=prof_closed,
            metrics={"requests_total": prof_closed["n_requests"]},
            trace=None,
        ))
    trace_meta = None
    if args.trace is not None:
        from repro.obs import export as obs_export
        args.trace.parent.mkdir(parents=True, exist_ok=True)
        k = min(args.trace_scenarios, n_tenants)
        ring_slice = jax.tree.map(lambda x: x[:k], lg_final)
        trace_meta = obs_export.write_merged_trace(
            str(args.trace), ring_slice, lg_labels[:k], server.obs)
        print(f"serve_latency/trace: {trace_meta['events_total']} events "
              f"({k} device rings + serve rows) -> {args.trace}")
    write(args.metrics_json, telemetry.record(
        "serve_metrics",
        run={"label": label, **run_common},
        profile={"pad_fraction": prof["pad_fraction"],
                 "defer_rate": prof["defer_rate"],
                 "serve_obs_overhead_frac": overhead_frac},
        metrics=server.obs.registry.snapshot(),
        trace=trace_meta,
    ))
    return 0 if (ok_tenants and ok_restart) else 1


if __name__ == "__main__":
    raise SystemExit(main())
