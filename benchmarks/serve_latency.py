"""ASA-as-a-service latency benchmark: replay an xsim fleet as traffic.

xsim doubles as the load generator: one batched sweep simulates a fleet
of ASA-driven workflow streams, and the per-stage (submit, start, wait)
events of every scenario are replayed — in event-time order — as live
requests against ``repro.serve.loop.ASAServer``.  Each scenario is one
tenant: its first request asks the stage-0 submit-lead-time (a pure
decision), then every observed stage wait feeds the tenant's posterior
(observe + decide in one request).  The serve loop batches the stream
through the jitted decision core exactly as production traffic would.

Reported (telemetry schema v1, kind ``serve_latency``):

* ``p50_ms`` / ``p99_ms`` — per-request decision latency, submit() to
  future resolution, across the whole replay;
* ``decisions_per_sec`` — total answered decisions over the replay wall
  time — the CI-gated sustained rate;
* run identity: tenants served, table slots, batch size, shard count.

The run ends with a **restart check**: the server state snapshots
through ``runtime.checkpoint``, a second server restores from it, and
every tenant's decision must be bitwise identical between the two — the
paper's estimator state survives a server restart exactly.  A mismatch
(or fewer than ``--min-tenants`` concurrent streams) exits non-zero.

  python -m benchmarks.serve_latency --smoke          # CI-sized replay
  python -m benchmarks.serve_latency                  # 3 replays
  python -m benchmarks.serve_latency --shards 8 --json bench/serve.json
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.obs import telemetry
from repro.serve.loop import ASAServer, ServeConfig
from repro.xsim import policies
from repro.xsim.grid import XSimConfig, make_grid, run_grid, stage_waits
from repro.xsim.state import ASA


def build_traffic(n_seeds: int, seed: int = 0):
    """Simulate a fleet and turn it into a request stream.

    Returns ``(events, n_tenants)`` where ``events`` is a list of
    ``(t_sim, tenant, observed_wait_or_None)`` sorted by simulated event
    time — the order a live fleet would have produced them.
    """
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=3600.0)
    grid = make_grid(cfg, policy_ids=(ASA,), n_seeds=n_seeds,
                     shrink=1 / 64.0, seed=seed)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    final, _ = run_grid(grid, fleet)
    waits, valid = stage_waits(final, cfg)
    sl = slice(cfg.max_jobs - cfg.max_stages, cfg.max_jobs)
    starts = np.asarray(final.start[:, sl])

    events: list[tuple[float, int, float | None]] = []
    for t in range(grid.n):
        # the stream opens with the stage-0 submit-lead query (pure
        # decision at the submission epoch) ...
        events.append((cfg.t0, t, None))
        # ... then every observed stage start feeds the posterior
        for y in range(cfg.max_stages):
            if valid[t, y]:
                events.append((float(starts[t, y]), t, float(waits[t, y])))
    events.sort(key=lambda e: (e[0], e[1]))
    return events, grid.n


def replay(server: ASAServer, events, replays: int) -> dict:
    """Open-loop replay: submit the stream as fast as the queue takes it,
    measure per-request latency (submit → future resolution) and the
    sustained decision rate."""
    lat: list[float] = []
    lat_lock = threading.Lock()

    def stamp(t_sub):
        def cb(fut):
            if fut.exception() is None:
                dt = time.perf_counter() - t_sub
                with lat_lock:
                    lat.append(dt)
        return cb

    futures = []
    t0 = time.perf_counter()
    for rep in range(replays):
        for _t_sim, tenant, wait in events:
            fut = server.submit(tenant, wait)
            fut.add_done_callback(stamp(time.perf_counter()))
            futures.append(fut)
    for fut in futures:
        fut.result(timeout=300)
    wall = time.perf_counter() - t0

    a = np.asarray(lat) * 1e3
    return {
        "n_requests": len(futures),
        "wall_s": wall,
        "p50_ms": float(np.percentile(a, 50)),
        "p99_ms": float(np.percentile(a, 99)),
        "mean_ms": float(a.mean()),
        "max_ms": float(a.max()),
        "decisions_per_sec": len(futures) / wall,
    }


def restart_check(server: ASAServer, cfg: ServeConfig, tenants: int,
                  mesh=None) -> bool:
    """Snapshot → restore → every tenant's decision bitwise-identical."""
    server.save(step=999)
    restored = ASAServer.restore(cfg, step=999, mesh=mesh)
    ok = True
    for batch_start in range(0, tenants, cfg.batch_size):
        ts = range(batch_start, min(batch_start + cfg.batch_size, tenants))
        fa = [server.submit(t) for t in ts]
        fb = [restored.submit(t) for t in ts]
        server.step_once(wait_s=0)
        restored.step_once(wait_s=0)
        for a, b in zip(fa, fb):
            da, db = a.result(timeout=60), b.result(timeout=60)
            if (da.lead_s, da.expected_s, da.entropy) != \
                    (db.lead_s, db.expected_s, db.entropy):
                print(f"restart_check: tenant {da.tenant} diverged: "
                      f"{da} vs {db}")
                ok = False
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one replay of the 1026-tenant stream (CI job)")
    ap.add_argument("--replays", type=int, default=None,
                    help="stream replays (default: 1 smoke, 3 full)")
    ap.add_argument("--seeds", type=int, default=57, metavar="N",
                    help="xsim seeds per grid cell; 18 cells × N seeds "
                         "tenants (default 57 -> 1026 tenants)")
    ap.add_argument("--slots", type=int, default=1536,
                    help="tenant-table capacity (default 1536)")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard_map the query axis over the first N "
                         "devices (default: single-device vmap)")
    ap.add_argument("--min-tenants", type=int, default=1000,
                    help="fail unless at least this many concurrent "
                         "tenant streams were served (default 1000)")
    ap.add_argument("--ckpt-dir", type=Path, default=None,
                    help="checkpoint dir for the restart check (default: "
                         "a tmp dir)")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the telemetry record (CI artifact)")
    args = ap.parse_args()
    if args.shards is not None:
        from repro.launch.mesh import shards_arg_error
        err = shards_arg_error(args.shards)
        if err is not None:
            ap.error(err)
        if args.batch_size % args.shards != 0:
            ap.error(f"--batch-size {args.batch_size} not divisible by "
                     f"--shards {args.shards}")
    replays = args.replays or (1 if args.smoke else 3)
    label = "smoke" if args.smoke else f"replay{replays}"

    t0 = time.perf_counter()
    events, n_tenants = build_traffic(args.seeds)
    loadgen_s = time.perf_counter() - t0
    n_obs = sum(1 for e in events if e[2] is not None)
    print(f"serve_latency/loadgen: {n_tenants} tenants, "
          f"{len(events)} events ({n_obs} observations) in {loadgen_s:.1f}s")
    if n_tenants > args.slots:
        ap.error(f"--slots {args.slots} < {n_tenants} tenants")

    ckpt_dir = args.ckpt_dir
    if ckpt_dir is None:
        import tempfile
        ckpt_dir = Path(tempfile.mkdtemp(prefix="serve_latency_ckpt_"))
    cfg = ServeConfig(n_slots=args.slots, batch_size=args.batch_size,
                      n_shards=args.shards,
                      checkpoint_dir=str(ckpt_dir))
    server = ASAServer(cfg)

    # warm the compile cache outside the timed replay (one compiled shape
    # serves every batch)
    t0 = time.perf_counter()
    warm = server.submit(0)
    server.step_once(wait_s=0)
    warm.result(timeout=300)
    compile_s = time.perf_counter() - t0

    server.start()
    try:
        prof = replay(server, events, replays)
    finally:
        server.stop()
    prof["compile_s"] = compile_s
    prof["loadgen_s"] = loadgen_s
    stats = server.stats
    prof["batches"] = stats["batches"]
    prof["batch_fill_mean"] = (stats["decisions"]
                               / max(stats["batches"], 1))

    sustained = stats["tenants"]
    ok_tenants = sustained >= args.min_tenants
    ok_restart = restart_check(server, cfg, n_tenants, mesh=server._mesh)

    shards = args.shards or 1
    print(f"serve_latency/{label}: p50={prof['p50_ms']:.2f}ms "
          f"p99={prof['p99_ms']:.2f}ms "
          f"decisions_per_sec={prof['decisions_per_sec']:.0f} "
          f"({prof['n_requests']} requests, {stats['batches']} batches, "
          f"fill={prof['batch_fill_mean']:.1f}/{args.batch_size}, "
          f"tenants={sustained}, shards={shards}, "
          f"backend={jax.default_backend()})")
    print(f"serve_latency/{label}/checks: tenants>={args.min_tenants}: "
          f"{'ok' if ok_tenants else 'FAIL'}; restart bitwise: "
          f"{'ok' if ok_restart else 'FAIL'}")

    rec = telemetry.record(
        "serve_latency",
        run={
            "label": label,
            "n_tenants": sustained,
            "n_slots": args.slots,
            "batch_size": args.batch_size,
            "n_shards": shards,
            "n_devices": len(jax.devices()),
            "backend": jax.default_backend(),
            "replays": replays,
            "loadgen_seeds": args.seeds,
            "restart_bitwise": ok_restart,
        },
        profile=prof,
        metrics={
            "requests_total": prof["n_requests"],
            "observations_total": n_obs * replays,
            "decisions_total": stats["decisions"],
            "deferred_end": stats["deferred"],
        },
        trace=None,
    )
    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(rec, indent=2))
    return 0 if (ok_tenants and ok_restart) else 1


if __name__ == "__main__":
    raise SystemExit(main())
