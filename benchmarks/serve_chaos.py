"""Chaos soak for the ASA serving loop: injected faults at open-loop rate.

Runs a :class:`repro.serve.loop.ServeSupervisor` under a deterministic
seeded fault mix (``repro.serve.chaos``) while a paced open-loop
producer streams synthetic tenant traffic at it, then gates the two
robustness invariants the ISSUE pins:

* **zero hung futures** — every submitted future (paced traffic AND the
  chaos injector's own queue bursts) must be resolved by soak end, with
  a Decision or a *typed* error; one unresolved future fails the run
  (exit 1), no tolerance;
* **recovery time** — for every injected fault, the wall seconds until
  the *next successful resolve* after it; the p99 over all faults is
  the gated headline (``profile.recovery_p99_ms``) — it covers step-
  exception containment (sub-batch), checkpoint-failure containment
  (~0), and the crash → supervised-restore-and-restart path (the tail).

Shedding is reported, not zero-gated: the soak *wants* pressure
(``--max-queue`` bounds ingress, a slice of requests carries deadlines,
bursts overshoot), so ``profile.shed_rate`` = shed / submitted is gated
against an absolute ceiling in ``benchmarks/baselines/serve_chaos.json``
— runaway shedding means the loop stopped digging out.

The traffic is synthetic (seeded tenant/wait draws, not the xsim
loadgen): chaos gating needs deterministic *fault* placement, not a
realistic wait mix, and the soak must fit the CI job's ≤ 2 min budget
including jit warmup.  Tenants deliberately outnumber table slots when
``--ttl`` is set, so the pool-lease LRU eviction path runs hot the whole
soak.

Emits one telemetry record, kind ``serve_chaos`` (schema v1), which
``benchmarks.bench_gate`` consumes:

  python -m benchmarks.serve_chaos --smoke --json bench/serve_chaos.json
  python -m benchmarks.serve_chaos --requests 20000 --rate 4000 \
      --chaos step=5,slow=2,ckpt=3,crash=2,burst=4 --max-queue 8192
"""

from __future__ import annotations

import argparse
import bisect
import json
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.obs import telemetry
from repro.serve import chaos as schaos
from repro.serve.loop import ServeConfig, ServeSupervisor


def parse_chaos_spec(spec: str, horizon: int, seed: int, *,
                     burst_size: int, slow_s: float) -> schaos.ChaosSchedule:
    """``step=3,slow=1,ckpt=2,crash=1,burst=2`` → a seeded
    :func:`repro.serve.chaos.mix_schedule` over ``horizon`` batches
    (``off`` → empty schedule)."""
    if spec == "off":
        return schaos.ChaosSchedule(())
    counts = {"step": 3, "slow": 1, "ckpt": 2, "crash": 1, "burst": 2}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        if k not in counts or not v.isdigit():
            raise SystemExit(
                f"serve_chaos: bad --chaos entry {part!r} "
                f"(want k=v with k in {sorted(counts)}, or 'off')")
        counts[k] = int(v)
    return schaos.mix_schedule(
        horizon, seed, step_exceptions=counts["step"],
        slow_steps=counts["slow"], checkpoint_errors=counts["ckpt"],
        crashes=counts["crash"], bursts=counts["burst"],
        burst_size=burst_size, slow_s=slow_s)


def run_soak(args) -> dict:
    schedule = parse_chaos_spec(args.chaos, args.horizon, args.seed,
                                burst_size=args.burst_size,
                                slow_s=args.slow_s)
    injector = schaos.ChaosInjector(schedule, seed=args.seed)
    cfg = ServeConfig(
        n_slots=args.slots, batch_size=args.batch_size,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
        seed=args.seed, max_queue=args.max_queue,
        tenant_ttl_s=args.ttl)
    sup = ServeSupervisor(cfg, chaos=injector,
                          max_restarts=args.max_restarts)
    rng = np.random.default_rng(args.seed)

    # success-resolve wall times, appended from resolver threads
    # (list.append is GIL-atomic); recovery is derived after the run
    ok_times: list[float] = []

    def stamp(fut) -> None:
        if fut.exception() is None:
            ok_times.append(time.monotonic())

    futures = []
    sup.start()
    try:
        # jit warmup outside the timed window (compile wall is not a
        # recovery time)
        sup.submit(0).result(timeout=300)
        t_start = time.monotonic()
        gap = 1.0 / args.rate if args.rate > 0 else 0.0
        next_due = t_start
        for i in range(args.requests):
            tenant = int(rng.integers(args.tenants))
            wait = float(rng.uniform(10.0, 4000.0)) \
                if rng.random() < 0.5 else None
            deadline = args.deadline_s \
                if args.deadline_s > 0 and i % 5 == 0 else None
            fut = sup.submit(tenant, wait, deadline_s=deadline)
            fut.add_done_callback(stamp)
            futures.append(fut)
            if gap:
                next_due += gap
                delay = next_due - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
        # flush: the schedule is keyed on dispatched batches, so keep a
        # trickle of traffic flowing until every scheduled fault has
        # fired (bounded — leftover faults fail the run below)
        flush_deadline = time.monotonic() + args.flush_timeout
        while injector.pending and time.monotonic() < flush_deadline:
            for _ in range(args.batch_size):
                tenant = int(rng.integers(args.tenants))
                wait = float(rng.uniform(10.0, 4000.0)) \
                    if rng.random() < 0.5 else None
                fut = sup.submit(tenant, wait)
                fut.add_done_callback(stamp)
                futures.append(fut)
            time.sleep(0.02)
        # let the loop dig out; every future must settle one way or
        # the other well inside this window
        drain_deadline = time.monotonic() + args.drain_timeout
        for fut in futures:
            remaining = drain_deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                fut.exception(timeout=remaining)
            except TimeoutError:
                break
        t_end = time.monotonic()
    finally:
        sup.stop()

    all_futures = futures + list(injector.burst_futures)
    hung = [f for f in all_futures if not f.done()]
    untyped = [f for f in all_futures
               if f.done() and f.exception() is not None
               and not isinstance(f.exception(), RuntimeError)]

    # recovery: per fired fault, wall seconds to the next successful
    # resolve; faults the run never recovered from charge to soak end
    ok_sorted = sorted(ok_times)
    recoveries_ms: list[float] = []
    recovery_by_kind: dict[str, list[float]] = {}
    unrecovered = 0
    for _batch, ev, t_f in injector.fired:
        i = bisect.bisect_right(ok_sorted, t_f)
        if i < len(ok_sorted):
            dt_ms = (ok_sorted[i] - t_f) * 1e3
        else:
            dt_ms = (t_end - t_f) * 1e3
            unrecovered += 1
        recoveries_ms.append(dt_ms)
        recovery_by_kind.setdefault(ev.kind, []).append(dt_ms)

    snap = sup.obs.registry.snapshot()
    submitted = int(snap.get("asa_serve_requests_total", 0))
    shed = int(snap.get("asa_serve_shed_total", 0))
    rec_arr = np.asarray(recoveries_ms) if recoveries_ms \
        else np.zeros(1)
    profile = {
        "recovery_p50_ms": float(np.percentile(rec_arr, 50)),
        "recovery_p99_ms": float(np.percentile(rec_arr, 99)),
        "recovery_max_ms": float(rec_arr.max()),
        "recovery_by_kind_ms": {
            k: round(float(np.max(v)), 3)
            for k, v in sorted(recovery_by_kind.items())},
        "hung_futures": len(hung),
        "untyped_errors": len(untyped),
        "unrecovered_faults": unrecovered,
        "shed_rate": shed / submitted if submitted else 0.0,
        "faults_fired": injector.counts(),
        "faults_pending": len(injector.pending),
        "restarts": sup.restarts,
        "duration_s": t_end - t_start,
        "n_requests": len(futures),
        "resolved": int(snap.get("asa_serve_resolved_total", 0)),
        "failed_typed": int(snap.get("asa_serve_failed_total", 0)),
    }
    run = {
        "label": args.label,
        "seed": args.seed,
        "n_tenants": args.tenants,
        "n_slots": args.slots,
        "batch_size": args.batch_size,
        "max_queue": args.max_queue,
        "tenant_ttl_s": args.ttl,
        "rate": args.rate,
        "chaos": args.chaos,
        "duration_s": t_end - t_start,
    }
    return telemetry.record("serve_chaos", run=run, profile=profile,
                            metrics=snap, trace=None)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized soak (~4k requests, fits ≤2 min "
                         "with jit warmup)")
    ap.add_argument("--requests", type=int, default=12000,
                    help="paced requests to submit (smoke: 4000)")
    ap.add_argument("--rate", type=float, default=3000.0,
                    help="open-loop submit rate, req/s (0 = unpaced)")
    ap.add_argument("--tenants", type=int, default=96,
                    help="tenant id space (> slots when --ttl is set, "
                         "so LRU eviction runs hot)")
    ap.add_argument("--slots", type=int, default=64,
                    help="tenant-table capacity")
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds traffic, fault placement and bursts")
    ap.add_argument("--chaos", default="step=3,slow=1,ckpt=2,crash=1,burst=2",
                    help="fault mix: step/slow/ckpt/crash/burst counts, "
                         "or 'off'")
    ap.add_argument("--horizon", type=int, default=24,
                    help="batch window the fault schedule is placed in "
                         "(the flush phase drives the loop through it)")
    ap.add_argument("--flush-timeout", type=float, default=60.0,
                    help="post-traffic wall budget for the trickle that "
                         "drives remaining scheduled faults to fire")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="bounded ingress: overflow sheds with "
                         "QueueFullError")
    ap.add_argument("--ttl", type=float, default=2.0,
                    help="tenant slot-lease TTL seconds (0 = no leases: "
                         "full table raises TableFullError)")
    ap.add_argument("--deadline-s", type=float, default=5.0,
                    help="every 5th request carries this relative "
                         "deadline (0 = none)")
    ap.add_argument("--burst-size", type=int, default=64,
                    help="requests per injected queue burst")
    ap.add_argument("--slow-s", type=float, default=0.05,
                    help="injected slow-device-step stall seconds")
    ap.add_argument("--ckpt-every", type=int, default=4,
                    help="checkpoint cadence in batches")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: a tempdir)")
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("--drain-timeout", type=float, default=120.0,
                    help="post-traffic wall budget for every future to "
                         "settle before it counts as hung")
    ap.add_argument("--label", default="chaos")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the serve_chaos telemetry record here")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 4000)
        args.label = "chaos-smoke"
    if args.ttl == 0:
        args.ttl = None

    tmp = None
    if args.ckpt_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="serve_chaos_ckpt_")
        args.ckpt_dir = tmp.name
    try:
        rec = run_soak(args)
    finally:
        if tmp is not None:
            tmp.cleanup()

    prof = rec["profile"]
    print(f"serve_chaos/{args.label}: "
          f"recovery p50={prof['recovery_p50_ms']:.1f}ms "
          f"p99={prof['recovery_p99_ms']:.1f}ms "
          f"max={prof['recovery_max_ms']:.1f}ms, "
          f"shed_rate={prof['shed_rate']:.3f}, "
          f"restarts={prof['restarts']}, "
          f"faults={sum(prof['faults_fired'].values())} "
          f"({prof['faults_pending']} pending), "
          f"hung={prof['hung_futures']} "
          f"untyped={prof['untyped_errors']} "
          f"({prof['n_requests']} requests in "
          f"{prof['duration_s']:.1f}s)")
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(rec, indent=2))
        print(f"serve_chaos: wrote {args.json}")

    ok = True
    if prof["hung_futures"]:
        print(f"serve_chaos: FAIL {prof['hung_futures']} futures never "
              "resolved (the zero-hung-futures invariant)",
              file=sys.stderr)
        ok = False
    if prof["untyped_errors"]:
        print(f"serve_chaos: FAIL {prof['untyped_errors']} futures "
              "failed with non-typed errors", file=sys.stderr)
        ok = False
    if prof["faults_pending"]:
        print(f"serve_chaos: FAIL {prof['faults_pending']} scheduled "
              "faults never fired (soak too short for the schedule "
              "horizon)", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
