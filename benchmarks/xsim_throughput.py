"""xsim fleet-throughput benchmark: scenarios/second of the batched engine.

Builds the full scenario grid (centers × scales × workflows × strategies
× seeds), runs it as ONE jitted ``vmap(lax.scan)`` program, and reports
scenarios/sec — the number the perf trajectory tracks from this PR on.

The JSON record (``--json``) is a schema-v1 ``repro.obs.telemetry``
record (kind ``xsim_throughput``): the gated throughput numbers live in
its ``profile`` section, the fleet counters/histograms
(``repro.obs.metrics``) in ``metrics``, and — when ``--trace`` is given
— the ring accounting in ``trace``. Tracing runs as a SECOND timed pass
(the gated numbers always come from the untraced sweep) and its
throughput cost is reported as ``profile.trace_overhead_frac``.

CSV rows: ``name,us_per_call,derived`` (benchmarks/run.py convention).

  python -m benchmarks.xsim_throughput            # ≥1000 scenarios
  python -m benchmarks.xsim_throughput --smoke    # CI-sized quick pass
  python -m benchmarks.xsim_throughput --shards 8 # device-parallel sweep
  python -m benchmarks.xsim_throughput --smoke --trace bench/trace.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry
from repro.xsim import backfill, events, policies
from repro.xsim.families import FAMILIES, family_grid
from repro.xsim.grid import XSimConfig, run_grid


def profile_record(final, cfg: XSimConfig, compile_s: float,
                   steady_s: float) -> dict:
    """Per-phase breakdown: where the sweep's steps (and seconds) went.

    ``steps_executed_*`` comes from the per-scenario ``steps`` counter
    (drained no-op steps don't count); the gap to ``steps_budget`` is the
    budget-bound → event-bound signal the trajectory tracks. Chunk count
    is derived, not measured: the drain exit is lockstep over a device's
    batch, so the busiest lane steps through every chunk the loop ran and
    ``chunks_run = ⌈max(steps) / chunk_steps⌉`` (per device — the max
    over devices when sharded; exact whenever the sweep drains, i.e.
    ``drained_frac == 1``, counting the static remainder scan as part of
    its preceding chunk).
    """
    steps = np.asarray(final.steps)
    drained = np.isinf(np.asarray(
        jax.jit(jax.vmap(events.next_event_time))(final)))
    chunks = (-(-int(steps.max()) // cfg.chunk_steps)
              if cfg.chunk_steps else 0)
    return {
        "steps_budget": cfg.n_steps,
        "chunk_steps": cfg.chunk_steps,
        "chunks_run": chunks,
        "steps_executed_max": int(steps.max()),
        "steps_executed_mean": float(steps.mean()),
        "steps_executed_min": int(steps.min()),
        "drained_frac": float(drained.mean()),
        "compile_s": compile_s,
        "steady_s": steady_s,
    }


def _timed_sweep(grid, fleet, reps: int, freed_mode: str,
                 n_shards: int | None):
    """(final, m, compile_s, steady_s) for one grid configuration."""
    t0 = time.time()
    final, m = run_grid(grid, fleet, freed_mode=freed_mode,
                        n_shards=n_shards)
    jax.block_until_ready(final)
    compile_s = time.time() - t0

    t0 = time.time()
    for r in range(reps):
        final, m = run_grid(grid, fleet, pred_seed=r + 2,
                            freed_mode=freed_mode, n_shards=n_shards)
        jax.block_until_ready(final)
    return final, m, compile_s, (time.time() - t0) / reps


def bench(n_seeds: int, reps: int, label: str,
          freed_mode: str = "ref", n_shards: int | None = None,
          trace_path: Path | None = None,
          trace_capacity: int | None = None,
          family: str = "clean") -> dict:
    base_cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16,
                          max_stages=9, t0=3600.0)
    grid = family_grid(base_cfg, family, n_seeds=n_seeds, shrink=1 / 64.0)
    cfg = grid.cfg  # family patches n_faults (and hence n_steps)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)

    final, m, compile_s, steady_s = _timed_sweep(grid, fleet, reps,
                                                 freed_mode, n_shards)

    done = float(np.mean(np.asarray(m["wf_done"])
                         / np.maximum(np.asarray(m["wf_total"]), 1)))
    sps = grid.n / steady_s
    shards = n_shards or 1
    print(f"xsim_throughput/{label},{steady_s * 1e6 / grid.n:.0f},"
          f"scenarios_per_sec={sps:.0f};per_device_sps={sps / shards:.0f};"
          f"n_scenarios={grid.n};n_shards={shards};"
          f"n_steps={cfg.n_steps};max_jobs={cfg.max_jobs};"
          f"compile_s={compile_s:.1f};wf_done_frac={done:.3f};"
          f"backend={jax.default_backend()};freed_mode={freed_mode}")

    profile = profile_record(final, cfg, compile_s, steady_s)
    profile.update(
        scenarios_per_sec=sps,
        per_device_scenarios_per_sec=sps / shards,
        us_per_scenario=steady_s * 1e6 / grid.n,
    )
    print(f"xsim_throughput/{label}/profile: "
          f"steps={profile['steps_executed_max']}max/"
          f"{profile['steps_executed_mean']:.1f}mean of "
          f"{profile['steps_budget']} budget; "
          f"chunks={profile['chunks_run']}x{profile['chunk_steps']}; "
          f"drained={profile['drained_frac']:.3f}; "
          f"compile={profile['compile_s']:.1f}s "
          f"steady={profile['steady_s']:.2f}s")

    metrics_final = final
    trace_sec = None
    if trace_path is not None:
        # tracing costs a second timed pass: the gated numbers above stay
        # untraced, and the traced pass prices its own overhead
        tcfg = cfg.with_trace(trace_capacity)
        tgrid = family_grid(tcfg, family, n_seeds=n_seeds, shrink=1 / 64.0)
        tfinal, _tm, tcompile_s, tsteady_s = _timed_sweep(
            tgrid, fleet, reps, freed_mode, n_shards)
        overhead = tsteady_s / steady_s - 1.0
        profile.update(trace_overhead_frac=overhead,
                       traced_steady_s=tsteady_s,
                       traced_compile_s=tcompile_s)
        trace_path.parent.mkdir(parents=True, exist_ok=True)
        trace_sec = obs_export.write_chrome_trace(str(trace_path), tfinal,
                                                  tgrid.labels)
        metrics_final = tfinal  # summary gains the ev_* event counters
        print(f"xsim_throughput/{label}/trace: "
              f"capacity={tcfg.trace_capacity}/scenario; "
              f"events={trace_sec['events_total']} "
              f"(dropped={trace_sec['events_dropped']}); "
              f"overhead={overhead:+.1%}; wrote {trace_path}")

    summary = obs_metrics.sweep_summary(metrics_final, n_steps=cfg.n_steps)
    rec = telemetry.record(
        "xsim_throughput",
        run={
            "label": label,
            "freed_mode": freed_mode,
            "n_shards": shards,
            "n_devices": len(jax.devices()),
            "backend": jax.default_backend(),
            "n_scenarios": grid.n,
            "n_steps": cfg.n_steps,
            "max_jobs": cfg.max_jobs,
            "reps": reps,
            "family": family,
            "traced": trace_path is not None,
            "in_scan_learning": True,  # within-run ASA learning always on
        },
        profile=profile,
        metrics=obs_metrics.to_host(summary),
        trace=trace_sec,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-sized run (fast, CPU-friendly)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--freed-mode",
                    choices=("auto", *backfill.FREED_MODES),
                    default="auto",
                    help="reservation-scan backend; auto = sorted Pallas "
                         "kernel on TPU, sorted jnp elsewhere; ref_n2 = "
                         "the O(n²) differential reference")
    ap.add_argument("--profile", action="store_true",
                    help="deprecated no-op: the per-phase breakdown is "
                         "always part of the telemetry record now")
    ap.add_argument("--trace", type=Path, default=None, metavar="OUT.json",
                    help="run a second, traced pass and export its event "
                         "rings as a Chrome trace (open in Perfetto); "
                         "overhead vs the untraced pass lands in "
                         "profile.trace_overhead_frac")
    ap.add_argument("--no-trace", action="store_true",
                    help="explicitly disable tracing (the default; "
                         "errors if combined with --trace)")
    ap.add_argument("--trace-capacity", type=int, default=None, metavar="C",
                    help="event-ring slots per scenario (default "
                         "4*max_jobs; requires --trace)")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard_map the scenario axis over the first N "
                         "devices (default: single-device vmap); fake N "
                         "CPU devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--family", choices=FAMILIES, default="clean",
                    help="robustness scenario family "
                         "(repro.xsim.families): clean (default, no "
                         "capacity events), faulty, elastic or preempt")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="also write the telemetry record as JSON (the CI "
                         "bench-trajectory artifact)")
    args = ap.parse_args()
    # upfront flag validation (same contract as the --shards check: fail
    # before any compilation happens, not after the untraced pass)
    if args.trace is not None and args.no_trace:
        ap.error("--trace and --no-trace are mutually exclusive")
    if args.trace_capacity is not None:
        if args.trace is None:
            ap.error("--trace-capacity requires --trace OUT.json")
        if args.trace_capacity < 1:
            ap.error(f"--trace-capacity must be >= 1, "
                     f"got {args.trace_capacity}")
    if args.shards is not None:
        from repro.launch.mesh import shards_arg_error
        err = shards_arg_error(args.shards)
        if err is not None:
            ap.error(err)
    mode = args.freed_mode
    if mode == "auto":
        mode = "tpu" if jax.default_backend() == "tpu" else "ref"
    if args.smoke:
        # 54 cells × 2 seeds = 108 scenarios
        rec = bench(n_seeds=2, reps=args.reps or 1, label="smoke",
                    freed_mode=mode, n_shards=args.shards,
                    trace_path=args.trace,
                    trace_capacity=args.trace_capacity,
                    family=args.family)
    else:
        # 54 cells × 19 seeds = 1026 scenarios in one batched program
        rec = bench(n_seeds=19, reps=args.reps or 2, label="sweep1k",
                    freed_mode=mode, n_shards=args.shards,
                    trace_path=args.trace,
                    trace_capacity=args.trace_capacity,
                    family=args.family)
    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
