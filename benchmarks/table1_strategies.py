"""Table 1: {Montage, BLAST, Statistics} × {BigJob, Per-Stage, ASA[, Naive]}
× 6 core scalings × 2 centers — TWT / makespan / core-hours + the paper's
normalized averages.

Paper's headline numbers this reproduces qualitatively:
  * ASA core-hours == Per-Stage (optimal; BigJob ≈ +43..53% over it),
  * ASA makespan within a few % of BigJob (paper: ~2%),
  * Per-Stage makespan blows up at the busy center (paper: +34–36% avg).
"""

from __future__ import annotations

import time

from repro.sched.runner import run_table1, summarize_table1


def run(seed: int = 0, include_naive: bool = False):
    t0 = time.time()
    res = run_table1(seed=seed, include_naive=include_naive)
    elapsed = time.time() - t0
    summary = summarize_table1(res)
    return res, summary, elapsed


def main():
    res, summary, elapsed = run()
    n = len(res.runs)
    for strat, d in sorted(summary.items()):
        print(f"table1_strategies/{strat},{elapsed * 1e6 / max(n, 1):.0f},"
              f"twt=+{d['twt']*100:.0f}%;makespan=+{d['makespan']*100:.0f}%;"
              f"ch=+{d['ch']*100:.0f}%")
    # paper Table-1 comparison row (normalized averages across workflows)
    print("table1_strategies/paper_ref,0,"
          "bigjob_ch=+53%;per_stage_makespan=+34%;asa_makespan=+2%")


if __name__ == "__main__":
    main()
