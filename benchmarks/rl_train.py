"""Train + evaluate the learned submission policy (repro.rl).

Runs the REINFORCE recipe over vmapped xsim rollouts, then a held-out
five-strategy comparison grid (BigJob / Per-Stage / ASA / ASA-Naive /
learned head, greedy actions). Prints ``name,us_per_call,derived`` CSV
rows (benchmarks/run.py convention) and — the CI ``rl-smoke`` contract —
**exits non-zero unless the trained head improves on the init policy's
held-out reward**. ``--json`` writes a schema-v1 ``repro.obs.telemetry``
record (kind ``rl_train``): reward curve, held-out eval, and the
per-iteration fleet counters from ``TrainResult.telemetry`` (the
artifact uploaded next to the bench-trajectory JSON).

  python -m benchmarks.rl_train --smoke          # CI-sized: 3 iterations
  python -m benchmarks.rl_train                  # full recipe (30 iters)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.rl import train as rl_train
from repro.xsim.grid import XSimConfig

# CI-sized recipe: tiny tables, 3 REINFORCE iterations, a few seconds of
# sweep per iteration — end-to-end train+eval well under 5 minutes on CPU.
SMOKE = dict(iters=3, n_seeds=8, lr=0.5,
             sim=XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16,
                            max_stages=9, t0=1800.0))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 3 iterations on a tiny grid")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--n-seeds", type=int, default=None,
                    help="episodes per grid cell per training iteration")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard_map rollout batches over the first N "
                         "devices (default: single-device vmap); the "
                         "training curve is bit-identical either way")
    ap.add_argument("--eval-seed", type=int, default=1234,
                    help="held-out ScenarioGrid background seed")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="write the reward-curve + eval record (CI "
                         "artifact)")
    args = ap.parse_args()

    kw = dict(SMOKE) if args.smoke else {}
    if args.iters is not None:
        kw["iters"] = args.iters
    if args.lr is not None:
        kw["lr"] = args.lr
    if args.n_seeds is not None:
        kw["n_seeds"] = args.n_seeds
    if args.shards is not None:
        from repro.launch.mesh import shards_arg_error
        err = shards_arg_error(args.shards)
        if err is not None:
            ap.error(err)
        kw["n_shards"] = args.shards
    cfg = rl_train.TrainConfig(**kw)
    if cfg.iters < 1:
        ap.error("--iters must be >= 1")

    t0 = time.time()
    res = rl_train.train(cfg)
    train_s = time.time() - t0

    t0 = time.time()
    fleet = rl_train.warmed_fleet(cfg, grid_seed=args.eval_seed)
    ev = rl_train.evaluate(res.params, cfg, eval_seed=args.eval_seed,
                           fleet=fleet)
    ev0 = rl_train.evaluate(res.init_params, cfg, eval_seed=args.eval_seed,
                            fleet=fleet)
    eval_s = time.time() - t0

    us_per_iter = train_s * 1e6 / max(cfg.iters, 1)
    for strat, d in sorted(ev.items()):
        print(f"rl_eval/{strat},0,twt_s={d['twt_s']:.0f};"
              f"oh_hours={d['oh_hours']:.3f};reward={d['reward']:.3f};"
              f"n={d['n']}")
    improved = ev["rl"]["reward"] > ev0["rl"]["reward"]
    vs_ps = ev["rl"]["twt_s"] <= ev["per_stage"]["twt_s"]
    vs_asa = ev["rl"]["twt_s"] <= 1.15 * ev["asa"]["twt_s"]
    print(f"rl_train/curve,{us_per_iter:.0f},"
          f"iters={cfg.iters};first={res.rewards[0]:.3f};"
          f"last={res.rewards[-1]:.3f};train_s={train_s:.1f};"
          f"eval_s={eval_s:.1f};init_eval={ev0['rl']['reward']:.3f};"
          f"trained_eval={ev['rl']['reward']:.3f};improved={improved};"
          f"beats_per_stage={vs_ps};within_15pct_asa={vs_asa}")

    if args.json is not None:
        from repro.obs import telemetry

        rec = telemetry.record(
            "rl_train",
            run={"label": "smoke" if args.smoke else "full",
                 "iters": cfg.iters, "lr": cfg.lr,
                 "n_seeds": cfg.n_seeds, "hidden": cfg.hidden,
                 "oh_weight": cfg.oh_weight, "seed": cfg.seed,
                 "smoke": bool(args.smoke), "n_shards": cfg.n_shards,
                 "eval_seed": args.eval_seed},
            profile={"train_s": train_s, "eval_s": eval_s,
                     "us_per_iter": us_per_iter},
            metrics={"rewards": res.rewards,
                     "entropies": res.entropies,
                     # per-iteration fleet observability counters
                     # (repro.obs.metrics over each rollout batch)
                     "iterations": res.telemetry,
                     "eval": ev, "init_eval": ev0,
                     "checks": {"improved": improved,
                                "beats_per_stage": vs_ps,
                                "within_15pct_asa": vs_asa}},
        )
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(rec, indent=2))

    if not improved:
        sys.exit("rl_train: trained policy did not improve on the init "
                 f"policy's held-out reward ({ev['rl']['reward']:.3f} vs "
                 f"{ev0['rl']['reward']:.3f})")
    if not (vs_ps and vs_asa):
        sys.exit("rl_train: acceptance comparison failed "
                 f"(rl={ev['rl']['twt_s']:.0f}s, "
                 f"per_stage={ev['per_stage']['twt_s']:.0f}s, "
                 f"asa={ev['asa']['twt_s']:.0f}s)")


if __name__ == "__main__":
    main()
