"""Table 2: prediction accuracy per job geometry — real WT vs ASA WT vs
perceived WT, hit/miss ratios, core-hour overhead losses."""

from __future__ import annotations

import time

from repro.sched.runner import run_table2


def run(seed: int = 0, n_submissions: int = 60):
    t0 = time.time()
    rows = run_table2(seed=seed, n_submissions=n_submissions)
    return rows, time.time() - t0


def main():
    rows, elapsed = run(n_submissions=30)  # 30 probes/geometry for CI speed
    per = elapsed * 1e6 / max(len(rows), 1)
    for r in rows:
        print(f"table2_accuracy/{r.workflow}_{r.center}_{r.scale},{per:.0f},"
              f"real={r.real_wt_h:.2f}h;asa={r.asa_wt_h:.2f}h;"
              f"pwt={r.pwt_h:.2f}h;hit={r.hit_ratio:.2f};"
              f"miss={r.miss_ratio:.2f};oh={r.oh_loss_h:.1f}h")


if __name__ == "__main__":
    main()
