"""Regenerate EXPERIMENTS.md tables from experiments/*.json artifacts.

    PYTHONPATH=src python -m benchmarks.tables [--section roofline|dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

EXP = Path(__file__).resolve().parent.parent / "experiments"


def _fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def roofline_table(tag: str | None = None) -> str:
    rows = []
    for f in sorted((EXP / "roofline").glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "fail":
            continue
        is_tagged = "__" in f.stem.replace(
            f"{r['arch']}__{r['shape']}", "")
        if tag is None and r.get("opts"):
            continue
        if tag is not None and not r.get("opts"):
            continue
        rows.append(r)
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | MODEL/HLO flops | roofline frac |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(r['compute_s'])} | "
            f"{_fmt_ms(r['memory_s'])} | {_fmt_ms(r['collective_s'])} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |")
    return "\n".join(out)


def dryrun_table() -> str:
    rows = []
    for f in sorted((EXP / "dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        rows.append(r)
    out = ["| arch | shape | mesh | status | compile s | temp GB (all dev) | "
           "collectives (static) |",
           "|---|---|---|---|---:|---:|---|"]
    for r in rows:
        if r["status"] == "ok":
            temp = r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
            cc = r.get("collectives", {}).get("count_by_kind", {})
            cstr = ",".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(cc.items()))
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                       f"{r.get('compile_s', 0):.1f} | {temp:.1f} | {cstr} |")
        elif r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP | — | — | {r['reason'][:40]} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL | — | — | {r.get('error', '')[:60]} |")
    return "\n".join(out)


def perf_compare(arch: str, shape: str) -> str:
    """Baseline vs every tagged variant for one cell."""
    base = None
    variants = []
    for f in sorted((EXP / "roofline").glob(f"{arch}__{shape}*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "fail":
            continue
        if r.get("opts"):
            variants.append((f.stem.split("__")[-1], r))
        else:
            base = r
    out = ["| variant | compute ms | memory ms | collective ms | dominant | "
           "frac |", "|---|---:|---:|---:|---|---:|"]
    for name, r in ([("baseline", base)] if base else []) + variants:
        out.append(
            f"| {name} | {_fmt_ms(r['compute_s'])} | "
            f"{_fmt_ms(r['memory_s'])} | {_fmt_ms(r['collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("roofline", "all"):
        print("## Roofline (baseline)\n")
        print(roofline_table())
    if args.section in ("dryrun", "all"):
        print("\n## Dry-run\n")
        print(dryrun_table())


if __name__ == "__main__":
    main()
