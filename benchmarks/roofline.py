import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Roofline: three-term roofline per (arch × shape) on the single-pod mesh.

Methodology (CPU container, TPU v5e target — see EXPERIMENTS.md):
  * XLA's HloCostAnalysis counts a while (scan) body ONCE, so the full
    scanned program undercounts FLOPs by the trip count. We therefore lower
    two UNROLLED reduced-depth variants of each cell (L_a, L_b layers, all
    scans unrolled) and extrapolate:  cost(L) = base + L · marginal, with
    marginal = (cost_b − cost_a) / (L_b − L_a).
  * collective bytes come from the same unrolled per-device HLO (every
    collective statically visible), extrapolated the same way.
  * per-device terms (the compiled module is the per-device partitioned
    program):
        compute_s    = flops_dev / PEAK_FLOPS
        memory_s     = hbm_bytes_dev / HBM_BW
        collective_s = coll_bytes_dev / ICI_BW
  * MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (+KV reads
    in the memory term) for decode; ratio MODEL/HLO flags remat/redundancy.

Hardware constants (TPU v5e): 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "roofline"
DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def _aux_layers(cfg):
    """Two reduced depths honoring structural constraints (zamba period)."""
    if cfg.attn_every:
        return cfg.attn_every, 2 * cfg.attn_every
    return 2, 4


def _reduce_layers(cfg, n, seq_len: int = 0):
    kw = {"n_layers": n}
    # cap UNROLLED chunk-scan length at 64 iterations: at 32k+ sequences the
    # WKV/SSD chunk loop would otherwise unroll into hundreds of bodies and
    # blow up CPU compile time. A larger analysis chunk slightly INFLATES the
    # intra-chunk FLOP subterm (∝ chunk) — documented upper bound,
    # EXPERIMENTS.md §Roofline.
    if cfg.rwkv and seq_len:
        c = max(cfg.rwkv.chunk, seq_len // 64)
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, chunk=c)
    if cfg.ssm and seq_len:
        c = max(cfg.ssm.chunk, seq_len // 64)
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=c)
    return dataclasses.replace(cfg, **kw)


def count_active_params(cfg) -> float:
    """Matmul (>=2D) params; MoE experts weighted by top_k/E."""
    from repro.train.step import init_params
    from functools import partial
    shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = 0.0
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        if leaf.ndim < 2 or "embed" in p:
            continue
        n = float(np.prod(leaf.shape))
        if "moe/w_" in p:
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def _bf16_params(p_shapes):
    import jax.numpy as jnp
    def conv(l):
        if l.ndim >= 2 and l.dtype == jnp.float32:
            return jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        return l
    return jax.tree.map(conv, p_shapes)


def lower_unrolled(cfg, shape, mesh, *, remat: str = "dots",
                   vocab_parallel: bool = False, use_flash: bool = False,
                   bf16_params: bool = False, kv_seq_shard: bool = False,
                   seq_shard: bool = False):
    """Lower+compile one unrolled cell; return (flops, bytes, coll_bytes)."""
    from repro.launch import specs as SPECS
    from repro.parallel.collectives import collective_stats
    from repro.parallel.sharding import ShardingRules
    from repro.train import optimizer as OPT
    from repro.train.step import init_params, make_train_step
    from repro.serve.step import make_decode_step, make_prefill_step
    from jax.sharding import NamedSharding, PartitionSpec as P
    from functools import partial

    rules = ShardingRules(mesh)
    p_shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    if bf16_params:
        p_shapes = _bf16_params(p_shapes)
    p_shard = rules.tree_shardings(p_shapes)

    if shape.kind == "train":
        o_shapes = jax.eval_shape(OPT.init, p_shapes)
        o_shard = OPT.AdamWState(step=NamedSharding(mesh, P()), m=p_shard,
                                 v=p_shard)
        batch = SPECS.train_batch_specs(cfg, shape)
        b_shard = SPECS.batch_shardings(batch, rules, mesh)
        if seq_shard:
            b_ax = (rules.fsdp
                    if shape.global_batch % rules.n_fsdp == 0 else None)
            from jax.sharding import NamedSharding as _NS
            b_shard = dict(b_shard)
            b_shard["tokens"] = _NS(mesh, P(b_ax, "model"))
            b_shard["labels"] = _NS(mesh, P(b_ax, "model"))
        step = make_train_step(cfg, remat=remat, unroll=True,
                               vocab_parallel=vocab_parallel,
                               use_flash=use_flash)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        with mesh:
            compiled = jitted.lower(p_shapes, o_shapes, batch).compile()
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, unroll=True, use_flash=use_flash)
        args = SPECS.prefill_args(cfg, shape)
        if seq_shard:
            # context parallelism: queries sharded over `model` along S —
            # the right axis when n_heads doesn't divide the model axis
            b_ax = (rules.fsdp if args[0].shape[0] % rules.n_fsdp == 0
                    else None)
            arg_sh = (NamedSharding(mesh, P(b_ax, "model")),) + tuple(
                NamedSharding(mesh, rules.batch_spec(a.shape[0], a.ndim))
                for a in args[1:])
        else:
            arg_sh = tuple(
                NamedSharding(mesh, rules.batch_spec(a.shape[0], a.ndim))
                for a in args)
        jitted = jax.jit(step, in_shardings=(p_shard,) + arg_sh)
        with mesh:
            compiled = jitted.lower(p_shapes, *args).compile()
    else:
        step = make_decode_step(cfg, unroll=True)
        args = SPECS.decode_args(cfg, shape)
        arg_sh = SPECS.decode_shardings(cfg, shape, rules, mesh,
                                        kv_seq_shard=kv_seq_shard)
        jitted = jax.jit(step, in_shardings=(p_shard,) + tuple(arg_sh),
                         donate_argnums=(2,))
        with mesh:
            compiled = jitted.lower(p_shapes, *args).compile()

    ca = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]),
            coll["bytes_by_kind"])


def analyze_cell(cfg, shape, mesh, *, remat: str = "dots",
                 **opts) -> dict:
    La, Lb = _aux_layers(cfg)
    t0 = time.time()
    S = shape.seq_len if shape.kind != "decode" else 0
    fa, ba, ca_, kinds_a = lower_unrolled(_reduce_layers(cfg, La, S), shape,
                                          mesh, remat=remat, **opts)
    fb, bb, cb, kinds_b = lower_unrolled(_reduce_layers(cfg, Lb, S), shape,
                                         mesh, remat=remat, **opts)
    L = cfg.n_layers
    def extrap(a, b):
        marg = (b - a) / (Lb - La)
        return max(a - La * marg, 0.0) + L * marg, marg
    flops, flops_marg = extrap(fa, fb)
    hbm, _ = extrap(ba, bb)
    coll, _ = extrap(ca_, cb)
    kinds = {k: extrap(kinds_a.get(k, 0), kinds_b.get(k, 0))[0]
             for k in set(kinds_a) | set(kinds_b)}

    n_act = count_active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        model_flops = 6.0 * n_act * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_act * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_act * shape.global_batch

    chips = mesh.devices.size
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    rec = {
        "arch": cfg.name, "shape": shape.name, "remat": remat,
        "opts": {k: v for k, v in opts.items() if v},
        "aux_layers": [La, Lb],
        "flops_dev": flops, "hbm_bytes_dev": hbm,
        "collective_bytes_dev": coll,
        "collective_by_kind_dev": kinds,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_global": model_flops,
        "model_flops_dev": model_flops / chips,
        "useful_ratio": (model_flops / chips) / flops if flops else 0.0,
        "roofline_fraction": (
            (model_flops / chips / PEAK_FLOPS)
            / max(compute_s, memory_s, collective_s)
            if max(compute_s, memory_s, collective_s) > 0 else 0.0),
        "tokens_global": tokens,
        "n_active_params": n_act,
        "chips": chips,
        "analysis_s": round(time.time() - t0, 1),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--tag", default="")
    ap.add_argument("--vocab-parallel", action="store_true")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--kv-seq-shard", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()
    opts = dict(vocab_parallel=args.vocab_parallel, use_flash=args.flash,
                bf16_params=args.bf16_params, kv_seq_shard=args.kv_seq_shard,
                seq_shard=args.seq_shard)

    from repro.configs import cells
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    todo = [(c, s) for c, s, skip in cells() if skip is None]
    if args.arch:
        todo = [t for t in todo if t[0].name == args.arch]
    if args.shape:
        todo = [t for t in todo if t[1].name == args.shape]
    for cfg, shape in todo:
        tag = f"__{args.tag}" if args.tag else ""
        out = OUT_DIR / f"{cfg.name}__{shape.name}{tag}.json"
        if out.exists():
            print(f"cached {out.name}")
            continue
        print(f"analyze {cfg.name} × {shape.name} ...", flush=True)
        try:
            rec = analyze_cell(cfg, shape, mesh, remat=args.remat, **opts)
            print(f"  dominant={rec['dominant']} "
                  f"compute={rec['compute_s']*1e3:.2f}ms "
                  f"memory={rec['memory_s']*1e3:.2f}ms "
                  f"coll={rec['collective_s']*1e3:.2f}ms "
                  f"roofline_frac={rec['roofline_fraction']:.3f}",
                  flush=True)
        except Exception as e:
            rec = {"arch": cfg.name, "shape": shape.name, "status": "fail",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2500:]}
            print(f"  FAIL {str(e)[:160]}", flush=True)
        out.write_text(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
