"""Fig. 9: total resource usage per workflow × strategy (incl. ASA OH)."""

from __future__ import annotations

import time
from collections import defaultdict

from repro.sched.runner import run_table1


def run(seed: int = 0):
    t0 = time.time()
    res = run_table1(seed=seed, include_naive=True)
    usage = defaultdict(float)
    for r in res.runs:
        usage[(r.workflow, r.strategy)] += r.core_hours
    return dict(usage), time.time() - t0


def main():
    usage, elapsed = run()
    per = elapsed * 1e6 / max(len(usage), 1)
    for (wf, strat), ch in sorted(usage.items()):
        print(f"fig9_usage/{wf}_{strat},{per:.0f},core_hours={ch:.1f}")


if __name__ == "__main__":
    main()
