"""Fig. 5: ASA estimation convergence under a step-changing true wait.

Reproduces the paper's 1000-iteration simulation with the three policies
(default / tuned repetition=50 / greedy). Reports per-policy hit-rate in the
final fifth of each truth segment (a convergence measure) and the regret
trajectory vs the Theorem-1 bound.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.convergence import simulate
from repro.core.regret import theorem1_bound


def run(T: int = 1000, seed: int = 3) -> list[dict]:
    rows = []
    for policy in ("default", "tuned", "greedy"):
        t0 = time.time()
        r = simulate(policy, T=T, seed=seed)
        dt = (time.time() - t0) * 1e6 / T
        seg = T // 5
        tail_hits = []
        for s in range(5):
            tail = r.hit[s * seg + (4 * seg) // 5:(s + 1) * seg]
            tail_hits.append(float(np.mean(tail)))
        bound = theorem1_bound(T, 53, int(r.rounds[-1]))
        rows.append({
            "policy": policy,
            "us_per_iter": round(dt, 1),
            "tail_hit_rate": round(float(np.mean(tail_hits)), 3),
            "final_regret": float(r.regret[-1]),
            "thm1_bound": round(bound, 1),
            "within_bound": bool(r.regret[-1] <= bound),
            "rounds": int(r.rounds[-1]),
        })
    return rows


def main():
    for row in run():
        print(f"fig5_convergence/{row['policy']},{row['us_per_iter']},"
              f"tail_hit={row['tail_hit_rate']};regret={row['final_regret']:.0f}"
              f";bound={row['thm1_bound']};ok={row['within_bound']}")


if __name__ == "__main__":
    main()
