"""ResourcePool accounting: exact release/revoke, expiry, and the pool
invariant under random operation mixes.

The invariant (``ResourcePool.check_invariants``):

    sum(claim.slices over live claims) == sum(claimed_per_alloc)
    0 <= claimed_per_alloc[a] <= alloc[a].slices
    no claim or counter references a dead allocation

The regression tests pin the two historical bugs this file exists for:
``remove_allocation`` used to drop a spanning claim WITHOUT handing its
slices back to the surviving allocations (capacity leaked until the
pool was rebuilt), and ``release`` gave back a "proportional" guess in
dict order instead of the exact per-allocation breakdown.  The property
test drives random claim/release/revoke/expiry mixes against the
invariant (strategies restricted to integers — the conftest fallback
stub supports only integers/floats/booleans).
"""

import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.pool import ResourcePool


def _assert_consistent(pool):
    errs = pool.check_invariants()
    assert errs == [], errs


# ----------------------------------------------------------- regressions
def test_revoking_spanning_claim_returns_surviving_capacity():
    """Two spanning claims + one allocation removal: the survivors'
    capacity must come back exactly (the historical leak: the dead
    claim's slices stayed counted against the surviving allocation)."""
    pool = ResourcePool()
    a1 = pool.add_allocation(4)
    a2 = pool.add_allocation(4)
    c1 = pool.claim(6)               # a1:4 + a2:2
    c2 = pool.claim(2)               # a2:2
    assert c1 is not None and c2 is not None
    assert pool.available() == 0
    revoked = pool.remove_allocation(a1.id)
    assert [c.id for c in revoked] == [c1.id]
    _assert_consistent(pool)
    # c1's 2 slices on a2 are free again; only c2's 2 remain claimed
    assert pool.available() == 2
    c3 = pool.claim(2)
    assert c3 is not None, "capacity leaked after spanning-claim revoke"
    assert pool.available() == 0
    _assert_consistent(pool)


def test_release_is_exact_not_proportional():
    """Release hands back the recorded per-allocation breakdown; a
    skewed spanning claim must restore every allocation exactly."""
    pool = ResourcePool()
    a1 = pool.add_allocation(5)
    a2 = pool.add_allocation(1)
    c = pool.claim(6)                # a1:5 + a2:1
    assert c.alloc_slices == {a1.id: 5, a2.id: 1}
    pool.release(c)
    _assert_consistent(pool)
    assert pool.available() == 6
    assert pool._claimed_per_alloc[a1.id] == 0
    assert pool._claimed_per_alloc[a2.id] == 0
    # double release is a no-op, not a negative counter
    pool.release(c)
    _assert_consistent(pool)
    assert pool.available() == 6


def test_revoke_fires_callbacks_with_the_dead_claim():
    pool = ResourcePool()
    a = pool.add_allocation(3)
    c = pool.claim(3)
    seen = []
    pool.on_revoke.append(lambda cl: seen.append(cl))
    pool.remove_allocation(a.id)
    assert seen == [c]
    _assert_consistent(pool)
    assert pool.available() == 0 and pool.claim(1) is None


# ----------------------------------------------------------------- expiry
def test_expired_allocation_lapses_and_revokes():
    """expires_at is actually consulted: the sweep lapses the
    allocation and revokes its claims through on_revoke."""
    pool = ResourcePool()
    pool.add_allocation(4, expires_at=10.0)
    a2 = pool.add_allocation(4)
    c = pool.claim(6, now=0.0)       # spans both
    assert c is not None
    revoked = []
    pool.on_revoke.append(lambda cl: revoked.append(cl.id))
    assert pool.available(now=5.0) == 2          # not yet expired
    assert revoked == []
    lapsed = pool.sweep_expired(11.0)
    assert [cl.id for cl in lapsed] == [c.id]
    assert revoked == [c.id]
    _assert_consistent(pool)
    # the surviving allocation is whole again
    assert pool.available() == a2.slices == 4


def test_expired_inventory_is_never_claimable():
    pool = ResourcePool()
    pool.add_allocation(8, expires_at=100.0)
    assert pool.claim(4, now=99.0) is not None
    assert pool.claim(4, now=100.0) is None      # deadline inclusive
    _assert_consistent(pool)
    assert pool.available(now=100.0) == 0


def test_claim_at_now_skips_expired_but_uses_live():
    pool = ResourcePool()
    pool.add_allocation(4, expires_at=10.0)
    live = pool.add_allocation(4, expires_at=1000.0)
    c = pool.claim(4, now=50.0)
    assert c is not None and c.alloc_slices == {live.id: 4}
    _assert_consistent(pool)


# ----------------------------------------------------------------- leases
def test_leased_claim_lapses_on_sweep_and_revokes():
    pool = ResourcePool()
    pool.add_allocation(4)
    c = pool.claim(2, expires_at=10.0)
    keep = pool.claim(2)                 # no lease: never swept
    revoked = []
    pool.on_revoke.append(lambda cl: revoked.append(cl.id))
    assert pool.sweep_expired(9.9) == []
    lapsed = pool.sweep_expired(10.0)    # deadline inclusive
    assert [cl.id for cl in lapsed] == [c.id]
    assert revoked == [c.id]
    _assert_consistent(pool)
    assert pool.available() == 2         # the lease's slices came back
    assert keep.id in pool._claims


def test_renew_pushes_the_deadline_and_reports_dead_leases():
    pool = ResourcePool()
    pool.add_allocation(2)
    c = pool.claim(1, expires_at=10.0)
    assert pool.renew(c, 100.0) is True
    assert pool.sweep_expired(50.0) == []   # renewed past the sweep
    pool.release(c)
    assert pool.renew(c, 200.0) is False    # dead claims say so
    _assert_consistent(pool)


def test_sweep_lapses_leases_in_deadline_then_id_order():
    """(expires_at, id) order is the serving layer's idle-LRU: the
    coldest lease lapses first, ties break on claim id."""
    pool = ResourcePool()
    pool.add_allocation(8)
    c_late = pool.claim(1, expires_at=30.0)
    c_early = pool.claim(1, expires_at=10.0)
    c_tie_a = pool.claim(1, expires_at=20.0)
    c_tie_b = pool.claim(1, expires_at=20.0)
    lapsed = pool.sweep_expired(40.0)
    assert [cl.id for cl in lapsed] == \
        [c_early.id, c_tie_a.id, c_tie_b.id, c_late.id]
    _assert_consistent(pool)
    assert pool.available() == 8


# --------------------------------------------------------------- property
@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_pool_invariant_under_random_op_mix(seed):
    """Random claim/release/revoke/expiry mixes never break the
    invariant, never leave negative free capacity, and never let a
    claimable request exceed what live healthy allocations hold."""
    rng = random.Random(seed)
    pool = ResourcePool()
    claims = []
    allocs = []
    now = 0.0
    for _ in range(60):
        now += rng.random() * 5.0
        op = rng.randrange(6)
        if op == 0 or not allocs:
            exp = now + rng.random() * 20.0 if rng.random() < 0.5 else None
            allocs.append(pool.add_allocation(rng.randint(1, 8),
                                              expires_at=exp))
        elif op == 1:
            c = pool.claim(rng.randint(1, 12), now=now)
            if c is not None:
                claims.append(c)
        elif op == 2 and claims:
            pool.release(claims.pop(rng.randrange(len(claims))))
        elif op == 3:
            dead = allocs.pop(rng.randrange(len(allocs)))
            pool.remove_allocation(dead.id)
        elif op == 4:
            pool.sweep_expired(now)
        else:
            assert pool.available(now=now) >= 0
        _assert_consistent(pool)
        live = sum(pool._claimed_per_alloc.values())
        total = sum(a.slices for a in pool._allocs.values())
        assert 0 <= live <= total
    # drain everything: releasing every live claim frees all capacity
    for c in list(pool._claims.values()):
        pool.release(c)
    _assert_consistent(pool)
    assert sum(pool._claimed_per_alloc.values()) == 0
