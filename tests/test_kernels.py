"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.kernel import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_ffn_ref, grouped_matmul_ref
from repro.kernels.moe_gmm.ops import grouped_ffn
from repro.kernels.rwkv6_scan.kernel import wkv6 as wkv6_kernel
from repro.kernels.rwkv6_scan.ops import wkv6 as wkv6_ops
from repro.kernels.rwkv6_scan.ref import wkv6_ref


@pytest.mark.parametrize("B,S,H,hd,causal,window", [
    (2, 256, 4, 64, True, 0),
    (1, 128, 2, 128, True, 0),
    (2, 256, 4, 64, False, 0),
    (1, 256, 2, 64, True, 128),
])
def test_flash_attention_sweep(B, S, H, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    exp = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(ks[i], (1, 128, 2, 64)).astype(dtype)
               for i in range(3))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    exp = attention_ref(q, k, v, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol)


@pytest.mark.parametrize("E,C,D,F", [
    (4, 128, 256, 512), (2, 256, 512, 512), (8, 128, 128, 1024),
])
def test_grouped_matmul_sweep(E, C, D, F):
    ks = jax.random.split(jax.random.PRNGKey(E), 2)
    x = jax.random.normal(ks[0], (E, C, D))
    w = jax.random.normal(ks[1], (E, D, F))
    out = grouped_matmul(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(grouped_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-4)


def test_grouped_ffn_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = jax.random.normal(ks[0], (2, 128, 256)) * 0.1
    wg = jax.random.normal(ks[1], (2, 256, 512)) * 0.05
    wu = jax.random.normal(ks[2], (2, 256, 512)) * 0.05
    wd = jax.random.normal(ks[3], (2, 512, 256)) * 0.05
    out = grouped_ffn(x, wg, wu, wd, force_interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(grouped_ffn_ref(x, wg, wu, wd)),
                               atol=1e-4)


@pytest.mark.parametrize("B,S,H,K,chunk", [
    (2, 128, 3, 16, 32), (1, 256, 2, 64, 64), (2, 64, 4, 8, 16),
])
def test_wkv6_kernel_sweep(B, S, H, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(B + S), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    out, state = wkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=True)
    exp_o, exp_s = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp_o), atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(exp_s),
                               atol=2e-5)


def test_wkv6_with_carried_state():
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    B, S, H, K = 2, 64, 4, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    st0 = jax.random.normal(ks[5], (B, H, K, K)) * 0.3
    out, state = wkv6_ops(r, k, v, w, u, chunk=16, state0=st0,
                          force_interpret=True)
    exp_o, exp_s = wkv6_ref(r, k, v, w, u, state0=st0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp_o), atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(exp_s),
                               atol=2e-5)
