"""Algorithm 1 unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import asa
from repro.core.bins import make_bins, nearest_bin
from repro.core.losses import asymmetric, log_distance, zero_one


def test_init_uniform():
    s = asa.init(53, jax.random.PRNGKey(0))
    p = np.asarray(s.p)
    assert p.shape == (53,)
    np.testing.assert_allclose(p, 1.0 / 53, rtol=1e-6)


def test_bins_paper_grid():
    b = make_bins(53)
    assert b.shape == (53,)
    assert b[0] == 10.0 and b[-1] == 100_000.0
    assert np.all(np.diff(b) > 0)
    # §4.3: density skewed to the 10s/100s decades
    assert np.sum(b < 1000) > 40


def test_nearest_bin_roundtrip():
    b = make_bins(53)
    for i in (0, 7, 20, 52):
        assert nearest_bin(b, b[i]) == i


@given(st.integers(min_value=2, max_value=97))
@settings(max_examples=10, deadline=None)
def test_bins_other_m(m):
    b = make_bins(m)
    assert b.shape == (m,)
    assert np.all(np.diff(b) > 0)


def test_update_keeps_distribution():
    s = asa.init(8, jax.random.PRNGKey(1))
    g = jnp.float32(1.0)
    for i in range(20):
        lv = zero_one(jnp.asarray(make_bins(8), jnp.float32),
                      jnp.float32(10.0 * (i + 1)))
        s, a = asa.step(s, lv, g, policy="default")
        p = np.asarray(s.p)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)
        assert np.all(p >= 0)


def test_round_closes_only_past_unit_loss():
    """Inner loop runs while max_a ℓ_ta ≤ 1 (Algorithm 1 line 3)."""
    s = asa.init(4, jax.random.PRNGKey(0))
    g = jnp.float32(1.0)
    # loss 1 on action 0: first observe -> max ℓ == 1 -> round NOT closed
    s1 = asa.observe(s, jnp.int32(0), jnp.float32(1.0), g)
    assert int(s1.rounds) == 0
    # second unit loss on same action -> max ℓ == 2 > 1 -> round closes
    s2 = asa.observe(s1, jnp.int32(0), jnp.float32(1.0), g)
    assert int(s2.rounds) == 1
    assert float(jnp.max(s2.round_loss)) == 0.0  # reset


def test_tuned_sharpens_on_truth():
    bins = jnp.asarray(make_bins(53), jnp.float32)
    s = asa.init(53, jax.random.PRNGKey(2))
    truth = 500.0
    g = jnp.float32(1.0)
    for _ in range(30):
        lv = zero_one(bins, jnp.float32(truth))
        s, _ = asa.step(s, lv, g, policy="tuned", repetitions=50)
    est = float(asa.map_wait(s, bins))
    assert abs(np.log(est) - np.log(truth)) < 0.3


def test_greedy_vs_default_convergence():
    from repro.core.convergence import simulate
    truth = np.full(300, 1000.0, dtype=np.float32)
    r_tuned = simulate("tuned", T=300, truth=truth, seed=5)
    assert r_tuned.hit[-50:].mean() > 0.5
    # estimates end near the truth
    assert abs(np.log(r_tuned.estimate[-1]) - np.log(1000.0)) < 0.5


@given(st.floats(min_value=10.0, max_value=1e5))
@settings(max_examples=20, deadline=None)
def test_losses_bounded(w):
    bins = jnp.asarray(make_bins(53), jnp.float32)
    for fn in (zero_one, log_distance, asymmetric):
        lv = np.asarray(fn(bins, jnp.float32(w)))
        assert lv.shape == (53,)
        assert np.all(lv >= 0) and np.all(lv <= 1.0 + 1e-6)
    # zero_one has exactly one zero
    assert int(np.sum(np.asarray(zero_one(bins, jnp.float32(w))) == 0)) == 1


def test_batched_estimators_independent():
    s = asa.init_batch(8, 3, jax.random.PRNGKey(0))
    bins = jnp.asarray(make_bins(8), jnp.float32)
    lv = jax.vmap(lambda w: zero_one(bins, w))(
        jnp.asarray([10.0, 1000.0, 100000.0], jnp.float32))
    for _ in range(30):
        s, _ = asa.batched_step(s, lv, jnp.float32(1.0))
    maps = jax.vmap(lambda st: asa.map_wait(st, bins))(s)
    est = np.asarray(maps)
    assert est[0] < est[1] < est[2]
