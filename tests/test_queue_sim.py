"""Queue-simulator invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sched.centers import HPC2N, UPPMAX
from repro.sched.queue_sim import QueueSim


def test_core_conservation():
    sim = QueueSim(HPC2N, seed=0)
    total = HPC2N.total_cores
    for t in range(0, 20000, 2000):
        sim.run_until(t)
        running = sum(sim.jobs[j].cores for _, j in sim.running
                      if not sim.jobs[j].canceled)
        assert 0 <= sim.free_cores <= total
        assert running + sim.free_cores == total


def test_job_lifecycle_and_fcfs_wait():
    sim = QueueSim(HPC2N, seed=1)
    sim.run_until(3600)
    j = sim.submit(28, 600, user="t")
    sim.run_until_job_ends(j)
    assert j.start_time is not None and j.end_time == j.start_time + 600
    assert j.wait_time >= 0


def test_dependency_blocks_start():
    sim = QueueSim(HPC2N, seed=2)
    sim.run_until(1800)
    a = sim.submit(28, 900)
    b = sim.submit(28, 300, depend_on=a.id)
    sim.run_until_job_ends(b)
    assert b.start_time >= a.end_time


def test_cancel_queued_and_running():
    sim = QueueSim(HPC2N, seed=3)
    sim.run_until(1800)
    a = sim.submit(28, 5000)
    sim.run_until_job_starts(a)
    sim.cancel(a)
    # cores returned (and possibly immediately re-consumed by queued jobs)
    assert a.canceled and all(jid != a.id for _, jid in sim.running)
    b = sim.submit(28, 50)
    sim.cancel(b)
    assert b.canceled


def test_hooks_fire_even_if_already_started():
    sim = QueueSim(HPC2N, seed=4)
    sim.run_until(1800)
    j = sim.submit(1, 100)
    sim.run_until_job_starts(j)
    fired = []
    sim.on_start(j, lambda job: fired.append(job.id))
    assert fired == [j.id]


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=5, deadline=None)
def test_random_streams_keep_invariants(seed):
    sim = QueueSim(UPPMAX, seed=seed)
    sim.run_until(7200)
    running = sum(sim.jobs[j].cores for _, j in sim.running
                  if not sim.jobs[j].canceled)
    assert running + sim.free_cores == UPPMAX.total_cores
    assert 0.0 <= sim.utilization() <= 1.0
