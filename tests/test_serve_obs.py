"""Server observability: registry, lifecycle spans, scrape, merged trace.

Pins the load-bearing contracts of the serve-side observability layer:

- the metrics registry is stdlib-only (imports without jax), counters
  are monotone, histograms bucket like the paper's m = 53 ladder, and
  the Prometheus text exposition is format-0.0.4 shaped;
- **span conservation**: every request entering ``submit()`` produces
  exactly one ``enqueue`` event and exactly one ``request`` resolve
  span — TableFullError resolutions and eviction races included — and
  ``requests_total == resolved_total + failed_total`` once drained;
- decisions are **bit-identical** with spans on, spans off, and on the
  uninstrumented pre-observability path (the registry is counters-only
  bookkeeping; it must never touch device numerics);
- ``stats`` keeps its PR-7 keys while no longer losing evicted tenants'
  request counts (folded into the registry at evict time);
- the merged Chrome trace interleaves serve pid rows with device event
  rings without id collisions and passes ``validate_chrome``;
- the scrape endpoint serves /metrics (Prometheus), /metrics.json and
  /stats from the stdlib HTTP server, with monotone counters between
  scrapes;
- the telemetry schema knows ``serve_metrics``, treats unknown kinds as
  warn-level (never a hard failure), and bench_gate keys open/closed
  serve legs apart, gates batching health, and fails when the
  serve_metrics leg is missing.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request
from collections import Counter as TallyCounter

import numpy as np
import pytest

from repro.obs import export as obs_export
from repro.obs import registry as reg
from repro.obs import telemetry
from repro.obs.serve_obs import (PHASES, SERVE_PID, SERVE_REQUEST_PID,
                                 ServeObs, serve_registry)
from repro.serve.loop import ASAServer, ServeConfig


def _cfg(**kw):
    kw.setdefault("n_slots", 8)
    kw.setdefault("batch_size", 4)
    return ServeConfig(**kw)


def _drain_all(server, futs, max_steps=64):
    steps = 0
    while any(not f.done() for f in futs):
        server.step_once(wait_s=0)
        steps += 1
        assert steps < max_steps, "requests not draining"
    return futs


# --------------------------------------------------------- registry unit


def test_geometric_buckets_shape_and_errors():
    b = reg.geometric_buckets(1e-4, 100.0)
    assert len(b) == reg.M_BUCKETS_DEFAULT == 53
    assert b[0] == pytest.approx(1e-4) and b[-1] == pytest.approx(100.0)
    assert list(b) == sorted(b)
    # constant ratio: geometric ladder like core.bins.make_bins
    r = np.diff(np.log(np.asarray(b)))
    np.testing.assert_allclose(r, r[0], rtol=1e-9)
    with pytest.raises(ValueError):
        reg.geometric_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        reg.geometric_buckets(2.0, 1.0)
    with pytest.raises(ValueError):
        reg.geometric_buckets(1.0, 2.0, n=1)


def test_counter_monotone_and_gauge():
    r = reg.Registry()
    c = r.counter("x_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3


def test_histogram_bucketing_and_overflow():
    h = reg.Histogram("lat", (1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):  # le is inclusive: 1.0 -> bucket 0
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == [1.0, 2.0, 4.0]
    assert snap["counts"] == [2, 0, 1, 1]  # last = +Inf overflow
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(104.5)
    h.observe_many([0.1, 9.0])
    assert h.snapshot()["counts"] == [3, 0, 1, 2]
    with pytest.raises(ValueError):
        reg.Histogram("bad", (3.0, 1.0))


def test_registry_get_or_create_and_kind_clash():
    r = reg.Registry()
    assert r.counter("a") is r.counter("a")
    with pytest.raises(TypeError):
        r.gauge("a")
    assert r.get("a").kind == "counter"
    assert r.get("nope") is None


def test_prometheus_text_format():
    r = reg.Registry()
    r.counter("asa_x_total", "things").inc(3)
    r.gauge("asa_depth").set(2.5)
    r.histogram("asa_lat", (1.0, 2.0), "waits").observe_many([0.5, 5.0])
    text = r.prometheus_text()
    lines = text.splitlines()
    assert "# HELP asa_x_total things" in lines
    assert "# TYPE asa_x_total counter" in lines
    assert "asa_x_total 3" in lines
    assert "# TYPE asa_depth gauge" in lines
    assert "asa_depth 2.5" in lines
    # cumulative buckets + the implicit +Inf, then sum/count
    assert 'asa_lat_bucket{le="1"} 1' in lines
    assert 'asa_lat_bucket{le="2"} 1' in lines
    assert 'asa_lat_bucket{le="+Inf"} 2' in lines
    assert "asa_lat_sum 5.5" in lines
    assert "asa_lat_count 2" in lines
    assert text.endswith("\n")


def test_registry_snapshot_and_json_line():
    r = serve_registry()
    r.counter("asa_serve_requests_total").inc(7)
    snap = r.snapshot()
    assert snap["asa_serve_requests_total"] == 7
    assert snap["asa_serve_request_latency_seconds"]["count"] == 0
    line = json.loads(r.json_line(ts=123.0))
    assert line["ts"] == 123.0
    assert line["asa_serve_requests_total"] == 7


def test_registry_stays_importable_without_jax():
    # the gate-side tooling reads snapshots from a bare checkout: the
    # registry module must never drag jax in (same contract as
    # repro.obs.telemetry)
    import importlib.util
    import subprocess
    import sys
    spec = importlib.util.find_spec("repro.obs.registry")
    src_root = spec.origin.rsplit("/repro/", 1)[0]
    code = ("import sys; sys.modules['jax'] = None\n"
            f"sys.path.insert(0, {src_root!r})\n"
            "import repro.obs.registry as r\n"
            "reg = r.Registry(); reg.counter('c').inc()\n"
            "assert 'c 1' in reg.prometheus_text().splitlines()\n")
    subprocess.run([sys.executable, "-c", code], check=True)


# ------------------------------------------------------ span conservation


def _tally(obs: ServeObs) -> TallyCounter:
    return TallyCounter(ev[1] for ev in obs.events)


def _request_rids(obs: ServeObs, name: str) -> list[int]:
    return [ev[6] for ev in obs.events
            if ev[1] == name and ev[2] == SERVE_REQUEST_PID]


def test_span_conservation_happy_path():
    server = ASAServer(_cfg(obs_spans=True, batch_size=8))
    futs = [server.submit(t % 3, observed_wait=50.0 * (1 + t % 4))
            for t in range(12)]
    _drain_all(server, futs)
    o = server.obs
    enq = _request_rids(o, "enqueue")
    res = _request_rids(o, "request")
    assert sorted(enq) == sorted(res)          # one resolve per enqueue
    assert len(set(enq)) == len(enq) == 12     # unique rids, all 12
    s = server.stats
    assert s["requests"] == 12
    assert int(o.c_resolved.value) + s["failed"] == 12
    assert o.g_inflight.value == 0


def test_span_conservation_table_full():
    server = ASAServer(_cfg(n_slots=1, batch_size=4, obs_spans=True))
    f_ok = server.submit(1)
    f_full = server.submit(2)                  # no slot left
    server.step_once(wait_s=0)
    assert f_ok.result(timeout=10).tenant == 1
    assert f_full.exception(timeout=10) is not None
    o = server.obs
    assert sorted(_request_rids(o, "enqueue")) == \
        sorted(_request_rids(o, "request"))
    # the failed request's span carries the error marker
    errors = [ev[7] for ev in o.events if ev[1] == "request"]
    assert errors.count("table_full") == 1
    assert _tally(o)["table_full"] == 1        # admission-lane instant
    assert server.stats["failed"] == 1
    assert server.stats["table_full"] == 1
    assert o.g_inflight.value == 0


def test_span_conservation_eviction_race():
    """A tenant evicted between submit and dispatch is re-admitted at
    batch-form time; the request still resolves exactly once."""
    server = ASAServer(_cfg(obs_spans=True))
    f0 = server.submit(5, observed_wait=700.0)
    server.step_once(wait_s=0)
    f0.result(timeout=10)
    f1 = server.submit(5)                      # in queue...
    server.evict(5)                            # ...tenant vanishes
    server.step_once(wait_s=0)
    d = f1.result(timeout=10)
    assert d.tenant == 5
    o = server.obs
    assert sorted(_request_rids(o, "enqueue")) == \
        sorted(_request_rids(o, "request"))
    assert _tally(o)["evict"] == 1
    assert server.stats["evicted_tenants"] == 1
    assert o.g_inflight.value == 0


def test_deferred_duplicates_conserve_and_count():
    server = ASAServer(_cfg(obs_spans=True, batch_size=8))
    f1 = server.submit(3, observed_wait=100.0)
    f2 = server.submit(3, observed_wait=200.0)  # same-batch duplicate
    f3 = server.submit(3)
    _drain_all(server, [f1, f2, f3])
    o = server.obs
    assert sorted(_request_rids(o, "enqueue")) == \
        sorted(_request_rids(o, "request"))
    # f2 deferred once, f3 deferred behind it (order preserved)
    assert int(o.c_deferrals.value) == _tally(o)["defer"] == 2
    r = o.rates()
    assert r["defer_rate"] == pytest.approx(2 / 3)


# ------------------------------------------------- bit-identity + stats


def test_decisions_bit_identical_spans_on_off():
    """The acceptance bar: the registry-off default path answers bitwise
    what the fully-instrumented server answers — observability is host
    bookkeeping only, it never touches device numerics."""
    traffic = [(t % 4, 60.0 * (1 + t % 5)) for t in range(16)]
    answers = []
    for spans in (False, True):
        server = ASAServer(_cfg(obs_spans=spans))
        futs = [server.submit(t, observed_wait=w) for t, w in traffic]
        _drain_all(server, futs)
        answers.append([(d.lead_s, d.expected_s, d.entropy)
                        for d in (f.result(timeout=10) for f in futs)])
        if not spans:
            assert len(server.obs.events) == 0   # no spans recorded
    assert answers[0] == answers[1]


def test_stats_keeps_evicted_tenant_request_counts():
    """The PR-7 stats() bug: evicting a tenant silently dropped its
    request counts.  Now the lifetime total folds into the registry at
    evict time and stats() reports it."""
    server = ASAServer(_cfg())
    for _ in range(3):
        f = server.submit(7, observed_wait=100.0)
        server.step_once(wait_s=0)
        f.result(timeout=10)
    f = server.submit(8)
    server.step_once(wait_s=0)
    f.result(timeout=10)
    server.evict(7)
    s = server.stats
    # backward-compatible PR-7 keys, same meanings
    for k in ("batches", "decisions", "tenants", "n_slots", "deferred"):
        assert k in s
    assert s["decisions"] == 4 and s["tenants"] == 1
    # the evicted tenant's lifetime is not lost
    assert s["evicted_tenants"] == 1
    assert s["evicted_requests"] == 3
    assert s["requests"] == 4
    # a second eviction accumulates
    server.evict(8)
    assert server.stats["evicted_requests"] == 4


def test_spans_off_takes_no_timestamps():
    o = ServeObs(spans=False)
    assert o.now() == 0.0
    o.enqueue(0, 1, 0.0)
    o.span("batch_form", 0.0, 0.0)
    o.instant("admit", 0.0)
    assert len(o.events) == 0 and o.events_dropped == 0


def test_span_buffer_bounded_drops_oldest():
    o = ServeObs(spans=True, span_capacity=4)
    for i in range(7):
        o.enqueue(i, 0, float(i))
    assert len(o.events) == 4
    assert o.events_dropped == 3
    assert [ev[6] for ev in o.events] == [3, 4, 5, 6]   # oldest dropped


# ------------------------------------------------------- chrome export


def _small_served_obs():
    server = ASAServer(_cfg(obs_spans=True))
    futs = [server.submit(t % 3, observed_wait=80.0 * (1 + t % 3))
            for t in range(9)]
    _drain_all(server, futs)
    return server.obs


def test_chrome_events_shape():
    o = _small_served_obs()
    evs = o.chrome_events()
    names = {e["name"] for e in evs}
    assert {"process_name", "serve_obs_meta", "enqueue",
            "request"} <= names
    by_pid = TallyCounter(e["pid"] for e in evs)
    assert by_pid[SERVE_PID] > 0 and by_pid[SERVE_REQUEST_PID] > 0
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
            assert "ts" in e
        elif e["ph"] == "i":
            assert e["s"] == "t"
    # loop-phase spans present under their documented names
    loop_names = {e["name"] for e in evs if e["pid"] == SERVE_PID}
    assert set(PHASES[:5]) <= loop_names
    # request-lane args carry rid + tenant
    req = next(e for e in evs if e["name"] == "request")
    assert {"rid", "tenant"} <= set(req["args"])


def test_merged_trace_serve_only(tmp_path):
    o = _small_served_obs()
    meta = obs_export.write_merged_trace(str(tmp_path / "m.json"),
                                         serve=o)
    obj = json.loads((tmp_path / "m.json").read_text())
    assert obs_export.validate_chrome(obj) == []
    assert obj["otherData"]["serve_pid"] == SERVE_PID
    assert obj["otherData"]["n_scenarios"] == 0
    assert meta["serve_events_kept"] == len(o.events)
    assert meta["serve_events_dropped"] == 0
    with pytest.raises(ValueError, match="needs"):
        obs_export.merged_chrome_trace()


@pytest.fixture(scope="module")
def traced_sweep():
    """A tiny traced xsim sweep: the device event rings the merged
    trace interleaves with the serve rows."""
    from repro.xsim import policies
    from repro.xsim.grid import XSimConfig, make_grid, run_grid
    from repro.xsim.state import ASA
    cfg = XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                     t0=1800.0).with_trace()
    grid = make_grid(cfg, center_names=("hpc2n",), workflows=("blast",),
                     policy_ids=(ASA,), n_seeds=1, shrink=1 / 64.0)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    final, _ = run_grid(grid, fleet, pred_seed=3)
    return final, grid.labels


def test_merged_trace_roundtrip_no_pid_collisions(tmp_path, traced_sweep):
    final, labels = traced_sweep
    o = _small_served_obs()
    path = tmp_path / "merged.json"
    meta = obs_export.write_merged_trace(str(path), final, labels, o)
    obj = json.loads(path.read_text())
    assert obs_export.validate_chrome(obj) == []
    pids = {e["pid"] for e in obj["traceEvents"]}
    scen = {p for p in pids if p < SERVE_PID}
    assert scen == set(range(obj["otherData"]["n_scenarios"]))
    assert {SERVE_PID, SERVE_REQUEST_PID} <= pids
    assert obj["otherData"]["serve_request_pid"] == SERVE_REQUEST_PID
    # both sources fully present: device events + serve events + metas
    n_serve = sum(1 for e in obj["traceEvents"] if e["pid"] >= SERVE_PID)
    assert n_serve == len(o.chrome_events())
    assert meta["events_total"] == len(obj["traceEvents"])
    # the reserved-pid guard trips instead of colliding
    fake = {"traceEvents": [], "displayTimeUnit": "ms",
            "otherData": {"format": "repro.obs.chrome_trace",
                          "version": 1, "n_scenarios": SERVE_PID + 1}}
    import unittest.mock as mock
    with mock.patch.object(obs_export, "chrome_trace",
                           return_value=fake):
        with pytest.raises(ValueError, match="reserved serve pid"):
            obs_export.merged_chrome_trace(final, labels, o)


# ------------------------------------------------------- scrape endpoint


def _get(port, path):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=10) as r:
        return r.status, r.headers.get("Content-Type"), r.read()


def test_scrape_endpoint_smoke():
    server = ASAServer(_cfg())
    port = server.serve_metrics_http(port=0)
    try:
        f = server.submit(1, observed_wait=100.0)
        server.step_once(wait_s=0)
        f.result(timeout=10)
        status, ctype, body = _get(port, "/metrics")
        assert status == 200 and "version=0.0.4" in ctype
        text = body.decode()
        assert "# TYPE asa_serve_requests_total counter" in text
        first = _scrape_value(text, "asa_serve_requests_total")
        # more traffic, scrape again: counters are monotone between
        # scrapes of one process (the registry contract CI smokes)
        f = server.submit(2)
        server.step_once(wait_s=0)
        f.result(timeout=10)
        _, _, body2 = _get(port, "/metrics")
        second = _scrape_value(body2.decode(), "asa_serve_requests_total")
        assert second == first + 1
        status, ctype, body = _get(port, "/metrics.json")
        assert status == 200 and ctype == "application/json"
        snap = json.loads(body)
        assert snap["asa_serve_requests_total"] == 2
        status, _, body = _get(port, "/stats")
        assert json.loads(body) == server.stats
        with pytest.raises(urllib.error.HTTPError):
            _get(port, "/nope")
        with pytest.raises(RuntimeError, match="already running"):
            server.serve_metrics_http(port=0)
    finally:
        server.stop_metrics_http()


def _scrape_value(text, name):
    for line in text.splitlines():
        if line.startswith(name + " "):
            return float(line.split()[1])
    raise AssertionError(f"{name} not exposed")


def test_metrics_port_config_starts_endpoint_with_loop():
    server = ASAServer(_cfg(metrics_port=0))
    server.start()
    try:
        port = server._http.server_address[1]
        status, _, _ = _get(port, "/metrics")
        assert status == 200
    finally:
        server.stop()
    assert server._http is None               # stop() tears it down


# ------------------------------------------------- checkpoint stall span


def test_checkpoint_stall_recorded(tmp_path):
    cfg = _cfg(checkpoint_dir=str(tmp_path / "ckpt"), obs_spans=True)
    server = ASAServer(cfg)
    f = server.submit(1)
    server.step_once(wait_s=0)
    f.result(timeout=10)
    server.save_async(step=1).result(timeout=30)
    server.save_async(step=2).result(timeout=30)  # collects handle 1
    o = server.obs
    assert int(o.c_checkpoints.value) == 2
    assert _tally(o)["checkpoint_stall"] == 1
    assert float(o.c_ckpt_stall_s.value) >= 0.0


# --------------------------------------------------- telemetry schema


def test_serve_metrics_kind_validates():
    rec = telemetry.record(
        "serve_metrics",
        run={"label": "t"},
        profile={"pad_fraction": 0.1, "defer_rate": 0.2,
                 "serve_obs_overhead_frac": 0.01},
        metrics={"asa_serve_requests_total": 5},
        trace=None)
    assert telemetry.validate(rec) == []
    leg = telemetry.serve_metrics_leg(rec)
    assert leg["pad_fraction"] == 0.1
    assert leg["asa_serve_requests_total"] == 5
    bad = {"telemetry_version": 1, "kind": "serve_metrics",
           "run": {}, "profile": {"pad_fraction": 0.1},
           "metrics": {}, "trace": None}
    errs = telemetry.validate(bad)
    assert any("defer_rate" in e for e in errs)
    with pytest.raises(ValueError, match="defer_rate"):
        telemetry.serve_metrics_leg(bad)


def test_unknown_kind_is_warn_level_not_failure():
    rec = {"telemetry_version": 1, "kind": "kind_from_the_future",
           "run": {}, "profile": {}, "metrics": {}, "trace": None}
    msgs = telemetry.validate(rec)
    assert len(msgs) == 1 and telemetry.is_warning(msgs[0])
    assert "kind" in msgs[0]
    assert telemetry.hard_errors(msgs) == []
    # record() accepts forward-compatible kinds (warn, not raise)...
    telemetry.record("kind_from_the_future", run={}, profile={},
                     metrics={}, trace=None)
    # ...but still hard-fails on a missing section, warnings aside
    broken = {"telemetry_version": 1, "kind": "kind_from_the_future",
              "run": {}, "metrics": {}, "trace": None}
    assert telemetry.hard_errors(telemetry.validate(broken)) != []


def test_serve_leg_flattens_mode_and_rates():
    rec = telemetry.record(
        "serve_latency",
        run={"label": "closed64", "mode": "closed", "n_shards": None},
        profile={"p50_ms": 3.0, "p99_ms": 30.0,
                 "decisions_per_sec": 1000.0, "pad_fraction": 0.8},
        metrics={"defer_rate": 0.1},           # older records: in metrics
        trace=None)
    leg = telemetry.serve_leg(rec)
    assert leg["mode"] == "closed"
    assert leg["pad_fraction"] == 0.8          # profile wins
    assert leg["defer_rate"] == 0.1            # metrics fallback
    # mode defaults open for pre-closed-loop records
    rec2 = telemetry.record(
        "serve_latency", run={"label": "smoke"},
        profile={"p50_ms": 1.0, "p99_ms": 2.0,
                 "decisions_per_sec": 5.0},
        metrics={}, trace=None)
    assert telemetry.serve_leg(rec2)["mode"] == "open"


# ------------------------------------------------------- bench_gate


def test_serve_leg_key_separates_modes():
    from benchmarks import bench_gate
    assert bench_gate.serve_leg_key({"mode": "open"}) == "serve"
    assert bench_gate.serve_leg_key({}) == "serve"
    assert bench_gate.serve_leg_key({"mode": "closed"}) == "serve-closed"
    assert bench_gate.serve_leg_key(
        {"mode": "closed", "n_shards": 8}) == "serve-closed-shards8"


def test_gate_serve_checks_latency_and_batching_health():
    from benchmarks import bench_gate
    baseline = {"legs": {
        "serve": {"decisions_per_sec": 1000.0, "pad_fraction_max": 0.5,
                  "defer_rate_max": 1.0},
        "serve-closed": {"p50_ms": 4.0, "p99_ms": 100.0,
                         "pad_fraction_max": 0.9},
    }}
    good = {
        "serve": {"decisions_per_sec": 1100.0, "pad_fraction": 0.3,
                  "defer_rate": 0.6},
        "serve-closed": {"p50_ms": 4.5, "p99_ms": 110.0,
                         "pad_fraction": 0.85,
                         "decisions_per_sec": 500.0},
    }
    rec, fails = bench_gate.gate_serve(good, baseline, tolerance=0.25)
    assert rec["ok"] and fails == []
    bad = {
        "serve": {"decisions_per_sec": 500.0, "pad_fraction": 0.7,
                  "defer_rate": 1.4},
        "serve-closed": {"p50_ms": 40.0, "p99_ms": 90.0,
                         "pad_fraction": 0.95},
    }
    rec, fails = bench_gate.gate_serve(bad, baseline, tolerance=0.25)
    assert not rec["ok"]
    named = " | ".join(fails)
    assert "decisions/sec" in named
    assert "pad_fraction" in named and "defer_rate" in named
    assert "p50" in named
    # a baseline-gated metric missing from the record must not pass
    rec, fails = bench_gate.gate_serve(
        {"serve": {"decisions_per_sec": 1100.0, "defer_rate": 0.1},
         "serve-closed": good["serve-closed"]},
        baseline, tolerance=0.25)
    assert any("no pad_fraction" in f for f in fails)


def test_missing_serve_metrics_leg_fails_the_gate(tmp_path):
    from benchmarks import bench_gate
    open_rec = telemetry.record(
        "serve_latency", run={"label": "smoke", "mode": "open"},
        profile={"p50_ms": 1.0, "p99_ms": 2.0,
                 "decisions_per_sec": 9000.0},
        metrics={}, trace=None)
    (tmp_path / "serve_latency_smoke.json").write_text(
        json.dumps(open_rec))
    legs, fails = bench_gate.collect_serve_metrics_legs(tmp_path)
    assert legs == {} and fails == []          # absence named in main()
    met = telemetry.record(
        "serve_metrics", run={"label": "smoke"},
        profile={"pad_fraction": 0.2, "defer_rate": 0.5,
                 "serve_obs_overhead_frac": 0.02},
        metrics={"asa_serve_requests_total": 10,
                 "asa_serve_deferrals_total": 5}, trace=None)
    (tmp_path / "serve_metrics_smoke.json").write_text(json.dumps(met))
    legs, fails = bench_gate.collect_serve_metrics_legs(tmp_path)
    assert fails == [] and "serve-metrics" in legs
    assert legs["serve-metrics"]["asa_serve_deferrals_total"] == 5
    # a malformed serve_metrics record is a NAMED failure
    (tmp_path / "serve_metrics_broken.json").write_text(json.dumps(
        {"telemetry_version": 1, "kind": "serve_metrics",
         "run": {"label": "oops"}, "profile": {}, "metrics": {},
         "trace": None}))
    _, fails = bench_gate.collect_serve_metrics_legs(tmp_path)
    assert any("oops" in f and "pad_fraction" in f for f in fails)
