"""xsim.grid edge cases: empty products, single-stage workflows,
degenerate (all-identical) batches, and bitwise determinism of the
jitted sweep — the reproducibility contract the RL training loop and the
CI bench trajectory both rely on.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.sched.workflows import Stage, Workflow
from repro.xsim import policies
from repro.xsim.grid import (XSimConfig, make_grid, run_grid, stage_waits,
                             warm_fleet)
from repro.xsim.state import PER_STAGE

CFG = XSimConfig(n_warm=12, n_backlog=8, n_arrivals=12, max_stages=9,
                 t0=1800.0)

SOLO = Workflow("solo", (Stage("only", True, 600.0, 0.5),))


def test_make_grid_empty_product_raises():
    with pytest.raises(ValueError, match="empty scenario grid"):
        make_grid(CFG, workflows=())
    with pytest.raises(ValueError, match="empty scenario grid"):
        make_grid(CFG, policy_ids=(), workflows=("statistics",))
    with pytest.raises(ValueError, match="empty scenario grid"):
        make_grid(CFG, n_seeds=0, workflows=("statistics",))


def test_single_stage_workflow_runs_and_reports():
    """A 1-stage workflow exercises the no-successor chain-hook path:
    stage_waits must mark exactly one valid column and warm_fleet must
    still learn from it."""
    grid = make_grid(CFG, center_names=("hpc2n",), workflows=(SOLO,),
                     policy_ids=(1, 2), n_seeds=2, scales=(28,))
    assert all(lab["workflow"] == "solo" for lab in grid.labels)
    final, m = run_grid(grid)
    assert np.all(np.asarray(m["wf_done"]) == 1)
    assert np.all(np.asarray(m["wf_total"]) == 1)
    waits, valid = stage_waits(final, CFG)
    assert waits.shape == (grid.n, CFG.max_stages)
    assert valid[:, 0].all() and not valid[:, 1:].any()
    # with one stage, perceived wait == the single stage's queue wait
    np.testing.assert_allclose(np.asarray(m["twt_s"]), waits[:, 0],
                               rtol=1e-5, atol=1e-3)
    fleet0 = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet0, grid, rounds=1)
    assert not np.allclose(np.asarray(fleet.log_p),
                           np.asarray(fleet0.log_p))


def test_warm_fleet_no_stagelike_scenarios_is_identity():
    """A BigJob-only grid offers no clean stage-0 samples: the §4.3 loop
    must leave every geometry's estimator untouched (masked update)."""
    grid = make_grid(CFG, center_names=("hpc2n",),
                     workflows=("statistics",), policy_ids=(0,),
                     n_seeds=2, scales=(28,))
    fleet0 = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet0, grid, rounds=2)
    np.testing.assert_array_equal(np.asarray(fleet.log_p),
                                  np.asarray(fleet0.log_p))
    np.testing.assert_array_equal(np.asarray(fleet.t),
                                  np.asarray(fleet0.t))


def test_all_scenarios_identical_stay_identical():
    """vmap purity: clones of one scenario (same background key, same
    cell) must produce identical rows through the whole batched sweep."""
    grid = make_grid(CFG, center_names=("hpc2n",),
                     workflows=("statistics",), policy_ids=(PER_STAGE,),
                     n_seeds=4, scales=(28,))
    grid.keys = jnp.tile(grid.keys[:1], (grid.n, 1))
    final, m = run_grid(grid, pred_seed=3)
    for name, arr in m.items():
        a = np.asarray(arr)
        np.testing.assert_array_equal(
            a, np.broadcast_to(a[:1], a.shape),
            err_msg=f"metric {name} diverged across identical scenarios")
    waits, valid = stage_waits(final, CFG)
    np.testing.assert_array_equal(waits, np.broadcast_to(waits[:1],
                                                         waits.shape))
    np.testing.assert_array_equal(valid, np.broadcast_to(valid[:1],
                                                         valid.shape))


def test_run_grid_bitwise_deterministic():
    """Fixed seeds ⇒ the whole jitted sweep is bitwise reproducible:
    final states, metrics and the §4.3 warm loop all replay exactly."""
    grid = make_grid(CFG, workflows=("statistics", "montage"),
                     policy_ids=(0, 1, 2), n_seeds=2)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fa, ma = run_grid(grid, fleet, pred_seed=11)
    fb, mb = run_grid(grid, fleet, pred_seed=11)
    for xa, xb in zip(jax.tree.leaves(ma), jax.tree.leaves(mb)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    for xa, xb in zip(jax.tree.leaves(fa), jax.tree.leaves(fb)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    wa = warm_fleet(fleet, grid, rounds=2)
    wb = warm_fleet(fleet, grid, rounds=2)
    for xa, xb in zip(jax.tree.leaves(wa), jax.tree.leaves(wb)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
