"""Docs drift gate: scripts/check_docs.py keeps the markdown honest.

Positive: the committed docs must be clean — every ``--flag`` and every
``python -m`` invocation a README mentions exists in the code. Negative:
a doc citing a missing module or a flag absent from the referenced
parsers must fail with a named ``file:line`` error (so the check can
never silently pass on drift).
"""

import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_repo_docs_are_clean(capsys):
    assert check_docs.main() == 0
    assert "clean" in capsys.readouterr().out


def test_checked_set_includes_the_readmes():
    names = {str(p.relative_to(REPO)) for p in check_docs.find_docs()}
    assert "README.md" in names
    assert "src/repro/xsim/README.md" in names
    # planning/reference material is deliberately out of scope
    assert "ISSUE.md" not in names
    assert "SNIPPETS.md" not in names


def test_parser_flags_reads_argparse_without_importing():
    flags = check_docs.parser_flags(REPO / "benchmarks" / "run.py")
    assert {"--engine", "--policy", "--family", "--json"} <= flags


def test_bogus_flag_fails(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("Run `python -m benchmarks.run --engine xsim "
                   "--no-such-flag`.\n")
    errs = check_docs.check_file(bad)
    assert len(errs) == 1
    assert "--no-such-flag" in errs[0] and "bad.md:1" in errs[0]
    assert check_docs.main([bad]) == 1


def test_missing_module_fails(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("See `python -m benchmarks.retired_entry_point`.\n")
    errs = check_docs.check_file(bad)
    assert len(errs) == 1 and "retired_entry_point" in errs[0]


def test_env_var_flags_and_uncited_docs_are_ignored(tmp_path):
    ok = tmp_path / "ok.md"
    ok.write_text("Set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                  "before `python -m benchmarks.xsim_throughput --smoke`.\n")
    assert check_docs.check_file(ok) == []
    no_cli = tmp_path / "no_cli.md"
    no_cli.write_text("A doc citing no local CLI may mention --whatever.\n")
    assert check_docs.check_file(no_cli) == []
