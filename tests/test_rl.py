"""repro.rl: featurizer, policy head, engine wiring, REINFORCE training.

The wiring tests pin the learned-policy branch of the xsim chain hook
(policy id 4): actions recorded into the replay buffers, leads actually
steering successor submissions, and — crucially — ASA/naive scenarios
bit-identical whether or not a params pytree is threaded through the
sweep (the RL branch must be invisible to every other policy).

The acceptance test trains the smoke recipe end-to-end on CPU and holds
the ISSUE bar: on a held-out ScenarioGrid seed the learned head's mean
perceived inter-stage wait is no worse than Per-Stage and within 15% of
ASA, and its held-out reward improves on the init policy's.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import asa
from repro.core.bins import make_bins
from repro.rl import features as F
from repro.rl import policy as P
from repro.rl import rollout
from repro.rl import train as T
from repro.sched.workflows import STATISTICS
from repro.xsim import events, policies
from repro.xsim import state as X
from repro.xsim.grid import XSimConfig, make_grid, run_grid
from repro.xsim.state import empty_table, freeze

BINS = jnp.asarray(make_bins(53), jnp.float32)

TINY_SIM = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                      t0=1800.0)


def _rl_scenario(seed=0):
    """A bare machine + one RL-policy statistics workflow."""
    t = empty_table(32)
    policies.add_workflow(t, 0, STATISTICS, 8, X.RL, t0=100.0)
    return freeze(t, total_cores=64.0, free_cores=64.0, policy=X.RL,
                  t0=100.0, est=asa.init(53, jax.random.PRNGKey(seed)))


# ------------------------------------------------------------- features
def test_posterior_features():
    st = asa.init(53, jax.random.PRNGKey(0))
    mw, ew, ent = np.asarray(asa.posterior_features(st, BINS))
    assert mw == pytest.approx(float(BINS[0]))      # uniform: argmax = bin 0
    assert ew == pytest.approx(float(jnp.mean(BINS)), rel=1e-5)
    assert ent == pytest.approx(np.log(53), rel=1e-5)


def test_observe_shape_and_ranges():
    s = _rl_scenario()
    obs = F.observe(s, jnp.int32(0), jnp.int32(0), jnp.float32(-jnp.inf),
                    jnp.float32(100.0), BINS)
    assert obs.shape == (F.N_FEATURES,)
    assert len(F.FEATURE_NAMES) == F.N_FEATURES
    o = np.asarray(obs)
    assert np.all(np.isfinite(o))
    assert o[0] == 1.0                        # bias
    assert o[1] == pytest.approx(1.0)         # empty machine: all free
    assert o[8] == 0.0                        # no predecessor: eta = 0
    assert 0.0 <= o[11] <= 1.0 + 1e-6         # normalized entropy


# ---------------------------------------------------------- policy head
def test_policy_head_shapes_and_logprob():
    params = P.init_params(jax.random.PRNGKey(1), hidden=16)
    obs = jax.random.normal(jax.random.PRNGKey(2), (5, F.N_FEATURES))
    lg = P.logits(params, obs)
    assert lg.shape == (5, X.M_BINS)
    a = P.act_greedy(params, obs)
    np.testing.assert_array_equal(np.asarray(a),
                                  np.argmax(np.asarray(lg), axis=-1))
    lp = P.log_prob(params, obs, a)
    ref = jax.nn.log_softmax(lg, axis=-1)
    np.testing.assert_allclose(
        np.asarray(lp),
        np.asarray(ref)[np.arange(5), np.asarray(a)], rtol=1e-6)
    # distribution normalizes
    np.testing.assert_allclose(np.exp(np.asarray(ref)).sum(-1), 1.0,
                               rtol=1e-5)


def test_act_sample_follows_distribution():
    """A strongly peaked head samples its peak almost always."""
    params = P.init_params(jax.random.PRNGKey(0), hidden=8)
    params = params._replace(b2=params.b2.at[17].set(50.0),
                             w2=jnp.zeros_like(params.w2),
                             w1=jnp.zeros_like(params.w1))
    obs = jnp.zeros(F.N_FEATURES)
    keys = jax.random.split(jax.random.PRNGKey(3), 64)
    acts = jax.vmap(lambda k: P.act_sample(params, obs, k))(keys)
    assert np.all(np.asarray(acts) == 17)


# --------------------------------------------------------- engine wiring
def test_chain_hook_records_and_steers():
    """The RL branch records one (obs, action) per stage and its chosen
    bin is the lead actually applied: successor submitted at
    max(admission, E_y − bins[a_{y+1}])."""
    params = P.init_params(jax.random.PRNGKey(4))
    s = _rl_scenario()
    fin = events.simulate(s, n_steps=120, params=params, rl_mode="greedy")
    n_stages = len(STATISTICS.stages)
    acts = np.asarray(fin.rl_act)
    assert np.all(acts[:n_stages] >= 0)          # every stage drew an action
    assert np.all(acts[n_stages:] == -1)         # padding slots untouched
    obs = np.asarray(fin.rl_obs)
    assert np.all(np.isfinite(obs[:n_stages]))
    assert np.all(obs[:n_stages, 0] == 1.0)      # bias feature present
    # the recorded bin IS the lead the cascade used (pred_wait entry)
    pw = np.asarray(fin.pred_wait)[:n_stages]
    np.testing.assert_allclose(pw, np.asarray(BINS)[acts[:n_stages]])
    # successor submit respects max(admission, E_y − a_{y+1})
    ee = np.asarray(fin.expected_end)[:n_stages]
    sub = np.asarray(fin.submit)[:n_stages]
    for y in range(1, n_stages):
        lead = float(BINS[acts[y]])
        assert sub[y] >= ee[y - 1] - lead - 1e-3
    assert int(np.asarray(fin.est.t)) >= 2 * n_stages  # estimator learned


def test_rl_rows_have_no_dependency_edge():
    cfg = TINY_SIM
    grid = make_grid(cfg, workflows=("statistics",), policy_ids=(X.RL,),
                     n_seeds=1)
    states = grid.build(policies.scenario_estimators(
        policies.init_fleet(int(grid.geo_idx.max()) + 1),
        jnp.asarray(grid.geo_idx)))
    deps = np.asarray(states.start_dep)
    rows = np.asarray(states.wf_rows)
    assert np.all(deps[np.asarray(states.is_wf)] == -1)
    nxt = np.asarray(states.wf_next)
    # cascade structure intact: every stage but the last has a successor
    for b in range(grid.n):
        valid = rows[b][rows[b] >= 0]
        assert np.all(nxt[b][valid[:-1]] == valid[1:])
        assert nxt[b][valid[-1]] == -1


def test_run_grid_requires_params_for_rl():
    grid = make_grid(TINY_SIM, workflows=("statistics",),
                     policy_ids=(X.RL,), n_seeds=1)
    with pytest.raises(ValueError, match="params"):
        run_grid(grid)
    with pytest.raises(ValueError, match="rl_mode"):
        run_grid(grid, params=P.init_params(jax.random.PRNGKey(0)),
                 rl_mode="bogus")


def test_params_threading_invisible_to_other_policies():
    """Threading a params pytree through the sweep must not change any
    non-RL scenario: the RL branch is selected per scenario by policy id,
    so an ASA/naive grid is bit-identical with and without it."""
    grid = make_grid(TINY_SIM, workflows=("statistics", "montage"),
                     policy_ids=(0, 1, 2, 3), n_seeds=2)
    final_a, m_a = run_grid(grid, pred_seed=5)
    final_b, m_b = run_grid(grid, pred_seed=5,
                            params=P.init_params(jax.random.PRNGKey(9)))
    for xa, xb in zip(jax.tree.leaves(m_a), jax.tree.leaves(m_b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    for xa, xb in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ------------------------------------------------------------- training
def test_reinforce_step_moves_logprob_with_advantage():
    """After one update, actions with positive advantage gain log-prob
    and negative-advantage actions lose it (the REINFORCE direction)."""
    params = P.init_params(jax.random.PRNGKey(7), hidden=16)
    B, S = 6, 4
    obs = jax.random.normal(jax.random.PRNGKey(8), (B, S, F.N_FEATURES))
    act = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, X.M_BINS)
    act = act.at[0, -1].set(-1)                     # one masked slot
    reward = jnp.asarray([3.0, 2.0, 1.0, -1.0, -2.0, -3.0])
    new, ent = T.reinforce_step(params, obs, act, reward, 0.1)
    assert float(ent) > 0.0
    mask = np.asarray(act) >= 0
    lp_old = np.asarray(P.log_prob(params, obs, jnp.maximum(act, 0)))
    lp_new = np.asarray(P.log_prob(new, obs, jnp.maximum(act, 0)))
    d_ep = ((lp_new - lp_old) * mask).sum(-1)
    assert d_ep[0] > 0.0 and d_ep[-1] < 0.0


def test_train_acceptance_vs_hand_designed():
    """ISSUE acceptance: the trained head, on a held-out ScenarioGrid
    seed, beats Per-Stage on mean perceived wait, lands within 15% of
    ASA, and improves on the init policy's held-out reward."""
    cfg = T.TrainConfig(iters=5, n_seeds=8, lr=0.5, sim=TINY_SIM)
    res = T.train(cfg)
    assert len(res.rewards) == 5 and len(res.entropies) == 5
    fleet = T.warmed_fleet(cfg, grid_seed=1234)
    ev = T.evaluate(res.params, cfg, eval_seed=1234, fleet=fleet)
    ev0 = T.evaluate(res.init_params, cfg, eval_seed=1234, fleet=fleet)
    assert set(ev) == {"bigjob", "per_stage", "asa", "asa_naive", "rl"}
    assert ev["rl"]["reward"] > ev0["rl"]["reward"]
    assert ev["rl"]["twt_s"] <= ev["per_stage"]["twt_s"]
    assert ev["rl"]["twt_s"] <= 1.15 * ev["asa"]["twt_s"]
    # the OH ledger is consistent: only the no-dependency policies pay it
    assert ev["asa"]["oh_hours"] == 0.0
    assert ev["per_stage"]["oh_hours"] == 0.0
