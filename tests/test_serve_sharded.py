"""Sharded serving: shard_map decision step ≡ single-device vmap, bit
for bit — new tables (posteriors AND PRNG keys) and decision batches —
on 1/2/4/8 shards, plus server-level durability through the sharded
path.  CI's ``xsim-sharded`` job fakes 8 CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_scenarios_mesh
from repro.parallel import fleet as pfleet
from repro.serve import asa as serve_asa
from repro.serve.loop import ASAServer, ServeConfig

N_DEV = len(jax.devices())

needs = pytest.mark.skipif  # readability alias for the device gates


def _query(n, seed=0):
    """A busy batch: repeated decision slots, unique observation slots
    (the invariant the host batcher guarantees)."""
    rng = np.random.default_rng(seed)
    slot = rng.integers(0, 12, n).astype(np.int32)
    has = np.zeros(n, bool)
    seen = set()
    for i in range(n):
        if int(slot[i]) not in seen and rng.random() < 0.7:
            seen.add(int(slot[i]))
            has[i] = True
    return serve_asa.QueryBatch(
        slot=jnp.asarray(slot),
        observed_wait=jnp.asarray(
            rng.uniform(20.0, 3000.0, n).astype(np.float32)),
        has_obs=jnp.asarray(has))


def _assert_tables_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_serve_step_sharded_bit_identical(k):
    if N_DEV < k:
        pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    table = serve_asa.init_table(16, seed=3)
    q = _query(24)
    qp, mask = pfleet.pad_batch(q, 32)          # 32 % k == 0 for all k
    ref_t, ref_d = serve_asa.serve_step(table, qp, mask)
    sh_t, sh_d = serve_asa.serve_step(table, qp, mask,
                                      mesh=make_scenarios_mesh(k))
    _assert_tables_equal(ref_t, sh_t)
    for la, lb in zip(ref_d, sh_d):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_steps_compose_bit_identical():
    """A whole *sequence* of sharded steps stays bitwise on the vmap
    trajectory (the replicated table never drifts across steps)."""
    mesh = make_scenarios_mesh(2)
    ref = sh = serve_asa.init_table(16, seed=1)
    for step in range(4):
        q = _query(24, seed=step)
        qp, mask = pfleet.pad_batch(q, 32)
        ref, _ = serve_asa.serve_step(ref, qp, mask)
        sh, _ = serve_asa.serve_step(sh, qp, mask, mesh=mesh)
        _assert_tables_equal(ref, sh)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_server_matches_vmap_server():
    """Two full servers — one vmap, one shard_map — fed identical
    request streams answer identical decisions."""
    cfg_v = ServeConfig(n_slots=16, batch_size=8)
    cfg_s = ServeConfig(n_slots=16, batch_size=8, n_shards=2)
    sv, ss = ASAServer(cfg_v), ASAServer(cfg_s)
    rng = np.random.default_rng(9)
    for _ in range(6):
        reqs = [(int(rng.integers(0, 10)),
                 float(rng.uniform(20, 2000))
                 if rng.random() < 0.6 else None)
                for _ in range(6)]
        fa = [sv.submit(t, w) for t, w in reqs]
        fb = [ss.submit(t, w) for t, w in reqs]
        while any(not f.done() for f in fa):
            sv.step_once(wait_s=0)
        while any(not f.done() for f in fb):
            ss.step_once(wait_s=0)
        for a, b in zip(fa, fb):
            da, db = a.result(timeout=10), b.result(timeout=10)
            assert (da.lead_s, da.expected_s, da.entropy) == \
                   (db.lead_s, db.expected_s, db.entropy)
    _assert_tables_equal(sv._table, ss._table)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_restart_bitwise(tmp_path):
    """Durability through the sharded path: save under shard_map
    serving, restore, and both servers continue bitwise identically."""
    cfg = ServeConfig(n_slots=16, batch_size=8, n_shards=2,
                      checkpoint_dir=str(tmp_path / "ckpt"))
    server = ASAServer(cfg)
    rng = np.random.default_rng(2)
    for _ in range(5):
        fut = server.submit(int(rng.integers(0, 6)),
                            float(rng.uniform(20, 2000)))
        server.step_once(wait_s=0)
        fut.result(timeout=10)
    server.save(step=1)
    restored = ASAServer.restore(cfg, step=1)
    # range(8) admits tenants NEITHER server has seen: post-restart
    # admissions (dirty mask + reset-key salt were checkpointed) must
    # also line up bitwise with the uninterrupted server's
    for t in range(8):
        fa = server.submit(t, observed_wait=444.0)
        fb = restored.submit(t, observed_wait=444.0)
        server.step_once(wait_s=0)
        restored.step_once(wait_s=0)
        a, b = fa.result(timeout=10), fb.result(timeout=10)
        assert (a.lead_s, a.expected_s, a.entropy) == \
               (b.lead_s, b.expected_s, b.entropy)
    _assert_tables_equal(server._table, restored._table)
