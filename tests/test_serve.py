"""ASA-as-a-service: decision semantics, batching invariants, durability.

The restart tests pin the ISSUE's acceptance bar literally: a server
restored from its checkpoint answers **bitwise-identical** decisions to
the uninterrupted server — posteriors AND per-slot PRNG keys — both
immediately after restore and after identical continued traffic.
"""

import shutil
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import asa as core_asa
from repro.core.bins import make_bins
from repro.parallel import fleet as pfleet
from repro.runtime import checkpoint as CKPT
from repro.serve import asa as serve_asa
from repro.serve.loop import ASAServer, ServeConfig, TableFullError

BINS = make_bins(53)


def _cfg(tmp_path=None, **kw):
    kw.setdefault("n_slots", 8)
    kw.setdefault("batch_size", 4)
    if tmp_path is not None:
        kw.setdefault("checkpoint_dir", str(tmp_path / "ckpt"))
    return ServeConfig(**kw)


def _decide(server, tenants):
    futs = [server.submit(t) for t in tenants]
    while any(not f.done() for f in futs):
        server.step_once(wait_s=0)
    return [f.result(timeout=10) for f in futs]


# ------------------------------------------------------------- decisions
def test_fresh_tenant_answers_prior_map():
    """A new tenant's lead time is the uniform prior's MAP = bins[0]."""
    server = ASAServer(_cfg())
    (d,) = _decide(server, [17])
    assert d.lead_s == pytest.approx(float(BINS[0]))
    # uniform posterior: entropy = ln m
    assert d.entropy == pytest.approx(float(np.log(53)), rel=1e-5)


def test_observations_move_the_posterior():
    """Repeated observations of a long wait pull the MAP to its bin —
    the tuned §4.5 update, same as the xsim engine applies."""
    server = ASAServer(_cfg())
    for _ in range(6):
        fut = server.submit(7, observed_wait=900.0)
        server.step_once(wait_s=0)
        d = fut.result(timeout=10)
    # bins are geometric; the MAP must land on the bin nearest 900s
    nearest = float(BINS[np.argmin(np.abs(np.asarray(BINS) - 900.0))])
    assert d.lead_s == pytest.approx(nearest)
    # update-then-decide: the answering posterior saw its own update,
    # so entropy has dropped strictly below the uniform ln m
    assert d.entropy < np.log(53) - 1e-3


def test_update_then_decide_within_one_batch():
    """A query that both observes and decides answers from the
    post-scatter table (its own fresh posterior), not the stale one."""
    table = serve_asa.init_table(4)
    q = serve_asa.QueryBatch(
        slot=jnp.array([2], jnp.int32),
        observed_wait=jnp.array([900.0], jnp.float32),
        has_obs=jnp.array([True]))
    qp, mask = pfleet.pad_batch(q, 4)
    new_table, dec = serve_asa.serve_step(table, qp, mask)
    # the decision row reflects the updated slot exactly
    row = jax.tree.map(lambda x: x[2], new_table)
    feats = core_asa.posterior_features(row, jnp.asarray(BINS, jnp.float32))
    assert float(dec.lead_s[0]) == float(feats[0])
    assert float(dec.entropy[0]) == float(feats[2])
    assert float(dec.entropy[0]) < np.log(53) - 1e-6


def test_pad_rows_never_touch_the_table():
    """pad_batch pads with copies of row 0 — including its observation.
    The mask must keep those copies out of the scatter."""
    table = serve_asa.init_table(4)
    q = serve_asa.QueryBatch(
        slot=jnp.array([1], jnp.int32),
        observed_wait=jnp.array([500.0], jnp.float32),
        has_obs=jnp.array([True]))
    qp, mask = pfleet.pad_batch(q, 8)
    assert int(mask.sum()) == 1
    once, _ = serve_asa.serve_step(table, qp, mask)
    # 8 padded copies of the same observing query must equal ONE update
    alone, _ = serve_asa.serve_step(
        table, jax.tree.map(lambda x: x[:1], qp), jnp.ones(1, bool))
    np.testing.assert_array_equal(np.asarray(once.log_p[1]),
                                  np.asarray(alone.log_p[1]))
    # untouched slots are bitwise the originals
    for s in (0, 2, 3):
        np.testing.assert_array_equal(np.asarray(once.log_p[s]),
                                      np.asarray(table.log_p[s]))
        np.testing.assert_array_equal(np.asarray(once.key[s]),
                                      np.asarray(table.key[s]))


# -------------------------------------------------------------- batching
def test_duplicate_observation_defers_preserving_order():
    """Second same-batch observation of a tenant (and all its later
    requests) defer to the next batch; both updates still apply, in
    submission order."""
    server = ASAServer(_cfg(batch_size=8))
    f1 = server.submit(3, observed_wait=100.0)
    f2 = server.submit(3, observed_wait=200.0)
    f3 = server.submit(3)                       # decide after both
    n = server.step_once(wait_s=0)
    assert n == 1 and f1.done() and not f2.done() and not f3.done()
    n = server.step_once(wait_s=0)
    assert n == 2 and f2.done() and f3.done()
    # reference: the same two updates applied sequentially to one row
    ref = core_asa.init(53, _slot_key(server, 3))
    assert f3.result().lead_s == pytest.approx(_two_step_map(ref))


def _slot_key(server, tenant):
    # fresh slots keep their init_table key; recompute tenant's row key
    slot = server._slot_of[tenant]
    fresh = serve_asa.init_table(server.cfg.n_slots, server.cfg.m,
                                 server.cfg.seed)
    return fresh.key[slot]


def _two_step_map(state):
    bins = jnp.asarray(BINS, jnp.float32)
    s = core_asa.learn_wait_if(state, bins, jnp.float32(100.0),
                               jnp.asarray(True))
    s = core_asa.learn_wait_if(s, bins, jnp.float32(200.0),
                               jnp.asarray(True))
    return float(core_asa.map_wait(s, bins))


def test_table_full_fails_the_future_not_the_loop():
    server = ASAServer(_cfg(n_slots=2, batch_size=4))
    f1 = server.submit(1)
    f2 = server.submit(2)
    f3 = server.submit(3)
    server.step_once(wait_s=0)
    assert f1.result(timeout=10) and f2.result(timeout=10)
    with pytest.raises(TableFullError):
        f3.result(timeout=10)
    # the loop survived: eviction frees a slot and serving continues
    server.evict(1)
    f4 = server.submit(4)
    server.step_once(wait_s=0)
    assert f4.result(timeout=10).tenant == 4


def test_evicted_slot_resets_on_reuse():
    server = ASAServer(_cfg(n_slots=1, batch_size=2))
    for _ in range(4):
        fut = server.submit(11, observed_wait=900.0)
        server.step_once(wait_s=0)
    assert fut.result(timeout=10).lead_s > float(BINS[0])
    server.evict(11)
    f = server.submit(12)
    server.step_once(wait_s=0)
    # the reused slot is back at the uniform prior
    assert f.result(timeout=10).lead_s == pytest.approx(float(BINS[0]))


def test_threaded_loop_serves_many_tenants():
    server = ASAServer(_cfg(n_slots=64, batch_size=16))
    server.start()
    try:
        futs = [server.submit(t, observed_wait=50.0 * (1 + t % 5))
                for t in range(48)]
        decs = [f.result(timeout=60) for f in futs]
    finally:
        server.stop()
    assert {d.tenant for d in decs} == set(range(48))
    assert server.stats["tenants"] == 48
    assert server.stats["deferred"] == 0


# ------------------------------------------------------------ durability
def _traffic(server, rounds=3):
    rng = np.random.default_rng(5)
    for r in range(rounds):
        for t in range(5):
            fut = server.submit(t, float(rng.uniform(20, 2000)))
            server.step_once(wait_s=0)
            fut.result(timeout=10)


def test_restart_is_bitwise_identical(tmp_path):
    cfg = _cfg(tmp_path)
    server = ASAServer(cfg)
    _traffic(server)
    server.save(step=3)
    restored = ASAServer.restore(cfg, step=3)

    # identical decisions right after restore
    da = _decide(server, range(5))
    db = _decide(restored, range(5))
    for a, b in zip(da, db):
        assert (a.lead_s, a.expected_s, a.entropy) == \
               (b.lead_s, b.expected_s, b.entropy)

    # identical continued traffic stays bitwise identical (PRNG keys
    # were restored exactly, so the tuned update's draws line up)
    for t in range(5):
        fa = server.submit(t, observed_wait=333.0)
        fb = restored.submit(t, observed_wait=333.0)
        server.step_once(wait_s=0)
        restored.step_once(wait_s=0)
        a, b = fa.result(timeout=10), fb.result(timeout=10)
        assert (a.lead_s, a.expected_s, a.entropy) == \
               (b.lead_s, b.expected_s, b.entropy)
    np.testing.assert_array_equal(np.asarray(server._table.log_p),
                                  np.asarray(restored._table.log_p))
    np.testing.assert_array_equal(np.asarray(server._table.key),
                                  np.asarray(restored._table.key))


def test_restore_latest_and_tenant_map(tmp_path):
    cfg = _cfg(tmp_path)
    server = ASAServer(cfg)
    _decide(server, [42, 7])
    server.evict(7)
    server.save(step=1)
    server.save(step=4)
    restored = ASAServer.restore(cfg)     # picks latest_step = 4
    assert restored._batches == 4
    assert restored._slot_of == server._slot_of
    assert set(restored._free) == set(server._free)
    # a freed slot of a restored table resets on reuse (unknown history)
    (d,) = _decide(restored, [99])
    assert d.lead_s == pytest.approx(float(BINS[0]))


def test_checkpoint_cadence_runs_async_saves(tmp_path):
    cfg = _cfg(tmp_path, checkpoint_every=2)
    server = ASAServer(cfg)
    _traffic(server, rounds=2)            # 10 batches -> 5 cadence saves
    server.stop()                         # collects the last handle
    assert CKPT.latest_step(cfg.checkpoint_dir) == 10


# ------------------------------------------------- checkpoint bug fixes
def test_save_async_failure_raises_at_join(tmp_path):
    """The daemon thread must not swallow exceptions: a failed
    background save re-raises from result()/join()."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file, not a directory")
    h = CKPT.save_async({"x": jnp.zeros(3)}, blocker / "sub", 1)
    with pytest.raises((NotADirectoryError, FileExistsError, OSError)):
        h.result(timeout=30)
    assert h.done()


def test_save_async_success_reports_path(tmp_path):
    h = CKPT.save_async({"x": jnp.arange(4)}, tmp_path, 2)
    path = h.result(timeout=30)
    assert path == tmp_path / "step_2"
    assert CKPT.latest_step(tmp_path) == 2


def test_server_save_async_failure_surfaces_on_next_save(tmp_path):
    cfg = _cfg(tmp_path)
    server = ASAServer(cfg)
    _decide(server, [1])
    h = server.save_async(step=1)
    h.result(timeout=30)
    # break the checkpoint dir: the NEXT save_async collects the failed
    # handle's result() and raises in the caller (the serve loop),
    # never silently
    shutil.rmtree(cfg.checkpoint_dir)
    Path(cfg.checkpoint_dir).write_text("now a file")
    server.save_async(step=2)
    with pytest.raises((NotADirectoryError, FileExistsError, OSError)):
        server.save_async(step=3)


def test_reused_tmp_dir_drops_stale_leaves(tmp_path):
    """A crashed save's leftover _tmp_step_* files must not leak into a
    later checkpoint of a *smaller* tree at the same step."""
    big = {"a": jnp.zeros(4), "b": jnp.ones(4)}
    small = {"a": jnp.zeros(4)}
    # simulate the crash: a tmp dir with the big tree's leaves, no
    # manifest (the rename never happened)
    tmp = tmp_path / "_tmp_step_5"
    tmp.mkdir()
    (tmp / "a.bin").write_bytes(b"stale")
    (tmp / "b.bin").write_bytes(b"stale")
    CKPT.save(small, tmp_path, 5)
    published = tmp_path / "step_5"
    names = {p.name for p in published.iterdir()}
    assert "b.bin" not in names, "stale leaf leaked into the checkpoint"
    r = CKPT.restore(small, tmp_path, 5)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.zeros(4))
