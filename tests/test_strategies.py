"""Strategy math (eqs. 1–2) + ASA behaviour."""

import pytest

from repro.sched.centers import HPC2N, UPPMAX
from repro.sched.queue_sim import QueueSim
from repro.sched.strategies import (ASAEstimator, run_asa, run_bigjob,
                                    run_per_stage)
from repro.sched.workflows import BLAST, MONTAGE, STATISTICS, WORKFLOWS


def test_eq1_eq2_core_hours():
    """Eq (1) vs (2): per-stage beats bigjob iff Σn_i < s·n (here: any
    workflow with a sequential stage)."""
    for wf in WORKFLOWS.values():
        n = 112
        assert wf.core_seconds(n) < wf.bigjob_core_seconds(n)


def test_montage_structure():
    assert len(MONTAGE.stages) == 9
    assert sum(s.parallel for s in MONTAGE.stages) == 4
    assert len(BLAST.stages) == 2
    assert len(STATISTICS.stages) == 4


def test_bigjob_single_wait():
    sim = QueueSim(HPC2N, seed=0)
    sim.run_until(3600)
    m = run_bigjob(sim, BLAST, 28, "hpc2n")
    assert len(m.stage_waits) == 1
    assert m.core_hours == pytest.approx(
        BLAST.bigjob_core_seconds(28) / 3600.0)


def test_per_stage_waits_accumulate():
    sim = QueueSim(HPC2N, seed=0)
    sim.run_until(3600)
    m = run_per_stage(sim, MONTAGE, 28, "hpc2n")
    assert len(m.stage_waits) == 9
    assert m.core_hours == pytest.approx(MONTAGE.core_seconds(28) / 3600.0)


def test_asa_with_dependencies_has_no_overhead():
    sim = QueueSim(UPPMAX, seed=0)
    sim.run_until(3600)
    est = ASAEstimator(seed=0)
    m = run_asa(sim, MONTAGE, 160, "uppmax", est, use_dependencies=True)
    assert m.oh_hours == 0.0
    assert m.core_hours == pytest.approx(MONTAGE.core_seconds(160) / 3600.0)
    assert len(m.stage_waits) == 9


def test_asa_beats_per_stage_on_busy_center():
    """The paper's core claim: ASA's perceived waits ≪ Per-Stage's waits
    when the queue is busy (UPPMAX). Estimator warm-started like §4.3."""
    est = ASAEstimator(seed=1)
    # warm up the estimator on the same geometry (state kept across runs)
    sim0 = QueueSim(UPPMAX, seed=7)
    sim0.run_until(3600)
    run_asa(sim0, MONTAGE, 320, "uppmax", est)

    sim1 = QueueSim(UPPMAX, seed=8)
    sim1.run_until(3600)
    asa_m = run_asa(sim1, MONTAGE, 320, "uppmax", est)
    sim2 = QueueSim(UPPMAX, seed=8)
    sim2.run_until(3600)
    ps_m = run_per_stage(sim2, MONTAGE, 320, "uppmax")
    assert asa_m.twt_s < 0.6 * ps_m.twt_s
    assert asa_m.core_hours <= ps_m.core_hours + 1e-6
