"""Property-based invariants of the xsim slotted engine.

Hypothesis drives randomized small scenarios (random machine fill, random
backlog/arrival mixes, every policy including ASA-Naive) through the
event scan step by step, asserting the invariants the engine's masked
array writes must never break:

* core conservation — Σ cores(RUNNING) + free == total at every step,
  and used cores never exceed capacity (min_free ≥ 0);
* status-ladder monotonicity — INVALID→PENDING→QUEUED→RUNNING→DONE only
  moves forward, except the two explicit ASA-Naive cancel edges
  (RUNNING→CANCELLED at a mispredicted start, CANCELLED→QUEUED at the
  resubmission);
* causality — start ≥ submit for every started job;
* estimator sanity — the in-scan ASA state stays a normalized
  distribution (finite log_p, logsumexp ≈ 0).

CI installs real ``hypothesis``; minimal environments fall back to the
deterministic replay stub in conftest.py (same API surface).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.bins import make_bins
from repro.sched.workflows import BLAST, MONTAGE, STATISTICS
from repro.xsim import backfill, events, policies
from repro.xsim import state as X
from repro.xsim.grid import XSimConfig, make_grid, run_grid
from repro.xsim.state import add_job, empty_table, freeze

MAX_JOBS = 24
TOTAL = 64.0
N_STEPS = 70
BINS = jnp.asarray(make_bins(53), jnp.float32)

# one jitted step for all examples (fixed shapes -> single compile)
_step = jax.jit(lambda s: events.sim_step(s, BINS))

POLICIES = (X.BIGJOB, X.PER_STAGE, X.ASA, X.ASA_NAIVE)
WORKFLOWS = (STATISTICS, BLAST, MONTAGE)

# forward edges of the ladder + the two explicit naive cancel edges
_EDGES = {
    (X.PENDING, X.QUEUED), (X.QUEUED, X.RUNNING), (X.RUNNING, X.DONE),
    (X.RUNNING, X.CANCELLED),   # naive miss: cancel at start instant
    (X.CANCELLED, X.QUEUED),    # naive resubmission re-enters the queue
}
# one sim_step can compose several edges at the same instant, but only in
# the step's fixed order (releases → admissions → scheduling pass → cancel
# hook): admit+start (P→R), admit+start+cancel (P/Q→C), resubmit+start
# (C→R). A completion can never share a step with the same row's start
# (durations are positive), so *→DONE composites stay impossible.
_ALLOWED = _EDGES | {
    (X.PENDING, X.RUNNING), (X.PENDING, X.CANCELLED),
    (X.QUEUED, X.CANCELLED), (X.CANCELLED, X.RUNNING),
}


def _random_scenario(seed: int, policy_i: int, fill: float):
    """A small random machine + backlog + one workflow, host-built."""
    rng = np.random.default_rng(seed)
    policy = POLICIES[policy_i % len(POLICIES)]
    wf = WORKFLOWS[seed % len(WORKFLOWS)]
    t = empty_table(MAX_JOBS)
    row = 0
    used = 0.0
    for _ in range(int(rng.integers(0, 7))):          # warm-start running
        c = float(rng.integers(1, 24))
        if used + c > fill * TOTAL:
            break
        d = float(rng.uniform(50.0, 5000.0))
        add_job(t, row, cores=c, duration=d, submit=0.0, status=X.RUNNING,
                start=0.0, end=float(rng.uniform(1.0, d)))
        used += c
        row += 1
    for _ in range(int(rng.integers(0, 6))):          # queued backlog
        add_job(t, row, cores=float(rng.integers(1, 32)),
                duration=float(rng.uniform(50.0, 5000.0)), submit=0.0,
                status=X.QUEUED)
        row += 1
    for _ in range(int(rng.integers(0, 5))):          # future arrivals
        add_job(t, row, cores=float(rng.integers(1, 32)),
                duration=float(rng.uniform(50.0, 5000.0)),
                submit=float(rng.uniform(1.0, 4000.0)), status=X.PENDING)
        row += 1
    t0 = float(rng.uniform(0.0, 2000.0))
    policies.add_workflow(t, row, wf, 8, policy, t0=t0)
    mode = "sample" if seed % 2 else "greedy"
    return freeze(t, total_cores=TOTAL, free_cores=TOTAL - used,
                  policy=policy, t0=t0, est_seed=seed, pred_mode=mode)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 3), st.floats(0.1, 0.95))
def test_invariants_hold_at_every_step(seed, policy_i, fill):
    s = _random_scenario(seed, policy_i, fill)
    prev_status = np.asarray(s.status)
    for _ in range(N_STEPS):
        s = _step(s)
        status = np.asarray(s.status)
        cores = np.asarray(s.cores)
        free = float(s.free)
        # --- core conservation, never over capacity -------------------
        used = float(np.sum(np.where(status == X.RUNNING, cores, 0.0)))
        assert used + free == pytest.approx(float(s.total), abs=1e-3)
        assert free >= -1e-3
        assert float(s.min_free) >= -1e-3
        # --- status ladder only moves along allowed edges -------------
        for a, b in zip(prev_status, status):
            if a != b:
                assert (int(a), int(b)) in _ALLOWED, (int(a), int(b))
        prev_status = status
        # --- causality ------------------------------------------------
        start = np.asarray(s.start)
        submit = np.asarray(s.submit)
        started = np.isfinite(start)
        assert np.all(start[started] >= submit[started] - 1e-3)
    # --- the in-scan estimator is still a normalized distribution -----
    log_p = np.asarray(s.est.log_p)
    assert np.all(np.isfinite(log_p))
    assert abs(float(jax.nn.logsumexp(s.est.log_p))) < 1e-3


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64),
       st.booleans())
def test_sorted_freed_matches_n2_reference_exactly(seed, n, force_ties):
    """The O(n log n) sorted reservation == the O(n²) pairwise reference,
    bit for bit, on random integer-core job tables — end-time ties (the
    searchsorted side="right" case) and non-running rows included. Core
    counts are integer-valued in every grid, so both the sorted cumsum
    and the reference's row-order sum are exact integer arithmetic and
    the two formulations must agree EXACTLY, not approximately."""
    rng = np.random.default_rng(seed)
    if force_ties:
        # few distinct end times over many rows ⇒ guaranteed tie runs
        ends = rng.choice([60.0, 600.0, 600.0, 3600.0, 86400.0], size=n)
    else:
        ends = rng.uniform(0.0, 1e5, n)
    cores = rng.integers(1, 512, n).astype(np.float32)
    running = rng.random(n) < 0.7
    ref = backfill._freed_math(jnp.asarray(ends, jnp.float32),
                               jnp.asarray(cores), jnp.asarray(running))
    fast = backfill._freed_sorted(jnp.asarray(ends, jnp.float32),
                                  jnp.asarray(cores), jnp.asarray(running))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fast))


_GRID_CFG = XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                       t0=1800.0)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grid_sweep_invariants(seed):
    """Random full grids (all four policies) keep capacity + completion
    invariants through the vmapped sweep."""
    grid = make_grid(_GRID_CFG, n_seeds=1, shrink=1 / 128.0,
                     workflows=("statistics",), policy_ids=(0, 1, 2, 3),
                     seed=seed)
    final, m = run_grid(grid)
    assert float(jnp.min(final.min_free)) >= 0.0
    running = np.asarray(final.status) == X.RUNNING
    used = np.sum(np.where(running, np.asarray(final.cores), 0.0), axis=1)
    np.testing.assert_allclose(used + np.asarray(final.free),
                               np.asarray(final.total), rtol=1e-5)
    # every scenario's workflow finished inside the static step budget
    assert np.all(np.asarray(m["wf_done"]) == np.asarray(m["wf_total"]))
    # OH only ever accrues on the naive policy
    oh = np.asarray(m["oh_hours"])
    pol = np.asarray(m["policy"])
    assert np.all(oh[pol != X.ASA_NAIVE] == 0.0)
    assert np.all(oh >= 0.0)


def test_full_grid_drains_within_budget():
    """Every scenario of a full default ``make_grid`` sweep (all centers,
    scales, workflows and the naive cancel/resubmit policy included) must
    have ``next_event_time == +inf`` at budget end — i.e. the tightened
    ``n_steps`` formula (2·max_jobs + 2·max_stages + 16: the 6·max_stages
    cascade term absorbed by the in-step hook drain, the surviving slack
    covering worst-case cancel detours) silently truncates NOTHING. The
    per-scenario ``steps`` counter must also sit strictly below the
    budget for at least some scenarios (the event-bound signal the
    ``--profile`` record tracks) and never above it."""
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=3600.0)
    grid = make_grid(cfg, n_seeds=2, shrink=1 / 64.0,
                     policy_ids=(0, 1, 2, 3))
    final, m = run_grid(grid)
    nxt = np.asarray(jax.jit(jax.vmap(events.next_event_time))(final))
    assert np.all(np.isinf(nxt)), (
        f"{int(np.sum(np.isfinite(nxt)))} scenarios still had events at "
        f"budget end (n_steps={cfg.n_steps})")
    assert np.all(np.asarray(m["wf_done"]) == np.asarray(m["wf_total"]))
    steps = np.asarray(final.steps)
    assert int(steps.max()) <= cfg.n_steps
    assert float(steps.mean()) < cfg.n_steps  # budget-bound no more
