"""Fault-tolerant serving: chaos schedules, crash containment and
recovery, checkpoint integrity, shedding, and pressure eviction.

Pins the ISSUE's robustness acceptance bars literally:

* a failing jitted step fails **that batch's** futures with a typed
  ``ServeStepError`` and the loop survives;
* a crashed loop restarts from the latest checkpoint and the restarted
  server's decisions are **bitwise** those of the uninterrupted run
  (nothing replayed);
* a corrupted latest checkpoint degrades to the previous verified step
  (``latest_step(verified=True)``), and plain ``restore`` of the
  corrupted step raises ``CheckpointCorruptError``;
* a full table with an idle tenant sheds the coldest lease through
  ``runtime.pool`` instead of raising ``TableFullError``;
* every submitted future resolves — with a Decision or a typed error —
  under any chaos interleaving (the hypothesis property at the end).
"""

import random
import tempfile
import time
import urllib.error
import urllib.request
from http.client import RemoteDisconnected
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime import checkpoint as CKPT
from repro.serve import asa as serve_asa
from repro.serve import chaos as schaos
from repro.serve.loop import (ASAServer, QueueFullError, RequestExpired,
                              ServeConfig, ServeSupervisor, ServerCrashed,
                              ServerStopped, TableFullError)


def _cfg(tmp_path=None, **kw):
    kw.setdefault("n_slots", 8)
    kw.setdefault("batch_size", 4)
    if tmp_path is not None:
        kw.setdefault("checkpoint_dir", str(tmp_path / "ckpt"))
    return ServeConfig(**kw)


def _decide(server, tenants):
    futs = [server.submit(t) for t in tenants]
    while any(not f.done() for f in futs):
        server.step_once(wait_s=0)
    return [f.result(timeout=10) for f in futs]


def _probe(server, tenants):
    """Decide-only probes: pure table reads, safe for bitwise compares
    regardless of batch composition."""
    return [(d.lead_s, d.expected_s, d.entropy)
            for d in _decide(server, tenants)]


# ------------------------------------------------------------- schedules
def test_chaos_event_validation():
    with pytest.raises(ValueError, match="unknown chaos kind"):
        schaos.ChaosEvent(0, "meteor_strike")
    with pytest.raises(ValueError, match="batch must be >= 0"):
        schaos.ChaosEvent(-1, "step_exception")
    with pytest.raises(ValueError, match="magnitude > 0"):
        schaos.slow_step(3, 0.0)
    with pytest.raises(ValueError, match="magnitude >= 1"):
        schaos.queue_burst(3, 0)


def test_chaos_schedule_sorts_and_rejects_duplicates():
    s = schaos.ChaosSchedule((schaos.crash(5), schaos.step_exception(1),
                              schaos.checkpoint_error(1)))
    assert [e.batch for e in s.events] == [1, 1, 5]
    # within a batch, CHAOS_KINDS order is the total firing order
    assert [e.kind for e in s.events[:2]] == \
        ["step_exception", "checkpoint_write_error"]
    with pytest.raises(ValueError, match="duplicate chaos event"):
        schaos.ChaosSchedule((schaos.crash(2), schaos.crash(2)))


def test_mix_schedule_is_deterministic():
    a = schaos.mix_schedule(20, seed=7)
    b = schaos.mix_schedule(20, seed=7)
    assert a.events == b.events
    assert len(a) == 9  # 3 step + 1 slow + 2 ckpt + 1 crash + 2 burst


def test_injector_fires_at_or_after_and_once():
    inj = schaos.ChaosInjector(schaos.ChaosSchedule(
        (schaos.step_exception(3),)))
    inj.before_device_step(0)          # before the arm batch: nothing
    assert len(inj.pending) == 1
    with pytest.raises(schaos.InjectedStepFault):
        inj.before_device_step(7)      # at-or-after: fires late, once
    assert inj.pending == ()
    inj.before_device_step(7)          # never re-fires
    assert inj.counts()["step_exception"] == 1


# ----------------------------------------------------------- containment
def test_step_exception_fails_the_batch_not_the_loop():
    inj = schaos.ChaosInjector(schaos.ChaosSchedule(
        (schaos.step_exception(0),)))
    server = ASAServer(_cfg(), chaos=inj)
    futs = [server.submit(t) for t in (1, 2, 3)]
    server.step_once(wait_s=0)
    for f in futs:
        err = f.exception(timeout=10)
        assert isinstance(err, serve_asa.ServeStepError)
        assert err.batch == 0
        assert isinstance(err.__cause__, schaos.InjectedStepFault)
    # the failed dispatch neither commits the table nor counts a batch
    assert server.stats["batches"] == 0
    assert server.stats["step_errors"] == 1
    # the loop survives: the very next step serves normally
    (d,) = _decide(server, [9])
    assert d.lead_s > 0
    assert server.stats["batches"] == 1


def test_checkpoint_write_error_is_contained(tmp_path):
    inj = schaos.ChaosInjector(schaos.ChaosSchedule(
        (schaos.checkpoint_error(0),)))
    server = ASAServer(_cfg(tmp_path, checkpoint_every=1), chaos=inj)
    _decide(server, [1, 2])            # cadence fires, injection raises
    assert server.stats["batches"] >= 1          # serving continued
    reg = server.obs.registry.snapshot()
    assert reg["asa_serve_checkpoint_failures_total"] >= 1
    # later cadences save normally once the fault has fired
    _decide(server, [3, 4])
    server.stop()                      # collects the async handle
    assert CKPT.latest_step(server.cfg.checkpoint_dir) is not None


# -------------------------------------------------------- crash recovery
def test_crash_recovery_is_bitwise_with_uninterrupted_run(tmp_path):
    """The acceptance bar: a supervisor-restarted server answers the
    exact decisions of a server that never crashed, because restore
    replays nothing — both continue from the same checkpoint bytes."""
    cfg = _cfg(tmp_path)
    ref = ASAServer(cfg)               # the uninterrupted reference
    for t in range(6):
        fut = ref.submit(t, observed_wait=250.0 * (t + 1))
        ref.step_once(wait_s=0)
        fut.result(timeout=10)
    ref.save(step=3)

    # the crashing run: same checkpoint on disk, then a crash before
    # any further traffic lands — the supervisor restores from step 3
    inj = schaos.ChaosInjector(schaos.ChaosSchedule(
        (schaos.crash(0),)))
    sup = ServeSupervisor(cfg, chaos=inj)
    sup.start()
    try:
        fut = sup.submit(0)            # trips the batch-boundary crash
        deadline = time.monotonic() + 30
        while sup.restarts == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sup.restarts == 1
        # the pre-crash future resolved one way or the other (typed)
        err = fut.exception(timeout=30)
        assert err is None or isinstance(err, ServerCrashed)
        # post-restart traffic serves
        assert sup.submit(1).result(timeout=30).lead_s > 0
    finally:
        sup.stop()

    # bitwise: restore the supervisor's recovery checkpoint directly
    # and probe decide-only against the uninterrupted reference
    restored = ASAServer.restore(cfg, step=3, verified=True)
    assert _probe(restored, range(6)) == _probe(ref, range(6))
    np.testing.assert_array_equal(np.asarray(restored._table.log_p),
                                  np.asarray(ref._table.log_p))
    np.testing.assert_array_equal(np.asarray(restored._table.key),
                                  np.asarray(ref._table.key))


def test_crash_drains_pending_with_typed_error():
    inj = schaos.ChaosInjector(schaos.ChaosSchedule((schaos.crash(0),)))
    server = ASAServer(_cfg(), chaos=inj)
    futs = [server.submit(t) for t in range(5)]
    with pytest.raises(schaos.InjectedCrash):
        server.step_once(wait_s=0)     # manual stepping: crash escapes
    server._crash(schaos.InjectedCrash("boom"))  # what _run would do
    for f in futs:
        assert isinstance(f.exception(timeout=10), ServerCrashed)
    with pytest.raises(ServerCrashed):
        server.submit(99)              # ingress rejects after a crash
    with pytest.raises(ServerCrashed, match="cannot start"):
        server.start()
    assert server.stats["crashes"] == 1


def test_watchdog_gauges_track_loop_health():
    server = ASAServer(_cfg())
    server.start()
    try:
        server.submit(1).result(timeout=30)
        snap = server.obs.registry.snapshot()
        assert snap["asa_serve_loop_healthy"] == 1.0
        assert snap["asa_serve_last_batch_age_seconds"] >= 0.0
    finally:
        server.stop()
    assert server.obs.registry.snapshot()["asa_serve_loop_healthy"] == 0.0


# ------------------------------------------------------------- integrity
def test_corrupted_latest_falls_back_to_verified_step(tmp_path):
    cfg = _cfg(tmp_path)
    server = ASAServer(cfg)
    _decide(server, [1, 2, 3])
    server.save(step=1)
    _decide(server, [4, 5])
    server.save(step=2)
    ckpt_dir = tmp_path / "ckpt"
    assert CKPT.verify_step(ckpt_dir, 2) == []

    # flip one byte in a leaf of the latest step
    leaf = sorted((ckpt_dir / "step_2").glob("*.bin"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    leaf.write_bytes(bytes(raw))

    assert CKPT.verify_step(ckpt_dir, 2) != []
    assert CKPT.latest_step(ckpt_dir) == 2              # unverified view
    assert CKPT.latest_step(ckpt_dir, verified=True) == 1
    with pytest.raises(CKPT.CheckpointCorruptError):
        ASAServer.restore(cfg, step=2)
    # verified restore degrades to the previous good step — and its
    # decisions are the step-1 server's, bitwise
    restored = ASAServer.restore(cfg, verified=True)
    assert restored._batches == 1
    ref = ASAServer.restore(cfg, step=1)
    assert _probe(restored, [1, 2, 3]) == _probe(ref, [1, 2, 3])


# --------------------------------------------------- shedding & eviction
def test_full_table_sheds_coldest_lease_not_table_full():
    cfg = _cfg(n_slots=4, tenant_ttl_s=30.0)
    server = ASAServer(cfg)
    for t in range(4):                 # fill the table, oldest first
        _decide(server, [t])
    for t in range(1, 4):              # touch 1..3: tenant 0 is coldest
        _decide(server, [t])
    (d,) = _decide(server, [77])       # full table: sheds, not fails
    assert d.lead_s > 0
    assert 77 in server._slot_of and 0 not in server._slot_of
    assert server.stats["lease_evictions"] == 1
    assert server.stats["table_full"] == 0


def test_idle_lease_expires_and_frees_the_slot():
    cfg = _cfg(n_slots=2, tenant_ttl_s=0.05)
    server = ASAServer(cfg)
    _decide(server, [1])
    time.sleep(0.08)                   # tenant 1's lease lapses
    _decide(server, [2])               # sweep frees it on admit
    _decide(server, [3])
    assert 1 not in server._slot_of
    assert {2, 3} <= set(server._slot_of)


def test_default_config_still_raises_table_full():
    server = ASAServer(_cfg(n_slots=2))
    _decide(server, [1, 2])
    fut = server.submit(3)
    server.step_once(wait_s=0)
    assert isinstance(fut.exception(timeout=10), TableFullError)


def test_in_batch_tenants_are_never_shed():
    """Every tenant of the forming batch is protected: when all slot
    holders are in THIS batch, the overflow tenant fails table-full —
    pressure eviction never steals a protected slot mid-batch (slot
    reuse inside one scatter would break the unique-slot invariant)."""
    cfg = _cfg(n_slots=2, batch_size=4, tenant_ttl_s=30.0)
    server = ASAServer(cfg)
    futs = [server.submit(t) for t in (10, 11, 12)]  # 3 tenants, 2 slots
    server.step_once(wait_s=0)
    assert futs[0].result(timeout=10).lead_s > 0
    assert futs[1].result(timeout=10).lead_s > 0
    assert isinstance(futs[2].exception(timeout=10), TableFullError)
    assert server.stats["lease_evictions"] == 0
    assert set(server._slot_of) == {10, 11}   # nobody was stolen from
    # once the batch has left, 12 admits by shedding an idle lease
    (d,) = _decide(server, [12])
    assert d.lead_s > 0 and server.stats["lease_evictions"] == 1


def test_queue_full_sheds_with_typed_error():
    server = ASAServer(_cfg(max_queue=2))
    f1, f2 = server.submit(1), server.submit(2)
    f3 = server.submit(3)
    assert isinstance(f3.exception(timeout=1), QueueFullError)
    assert server.stats["shed"] == 1
    reg = server.obs.registry.snapshot()
    assert reg["asa_serve_shed_queue_full_total"] == 1
    while not (f1.done() and f2.done()):  # the accepted two still serve
        server.step_once(wait_s=0)
    assert f1.result(timeout=10).lead_s > 0
    assert f2.result(timeout=10).lead_s > 0


def test_deadline_shed_at_batch_form():
    server = ASAServer(_cfg())
    dead = server.submit(1, deadline_s=1e-6)
    live = server.submit(2, deadline_s=60.0)
    time.sleep(0.01)
    server.step_once(wait_s=0)
    assert isinstance(dead.exception(timeout=10), RequestExpired)
    assert live.result(timeout=10).lead_s > 0
    reg = server.obs.registry.snapshot()
    assert reg["asa_serve_shed_expired_total"] == 1
    assert reg["asa_serve_shed_total"] == 1


# ------------------------------------------------------------- lifecycle
def test_stop_drains_and_fails_queued_with_server_stopped():
    server = ASAServer(_cfg())
    futs = [server.submit(t) for t in range(4)]   # never stepped
    server.stop()
    for f in futs:
        assert isinstance(f.exception(timeout=10), ServerStopped)
    with pytest.raises(ServerStopped):
        server.submit(99)
    assert server.obs.registry.snapshot()[
        "asa_serve_stop_drained_total"] == 4


def test_repeated_stop_is_idempotent():
    server = ASAServer(_cfg())
    server.start()
    server.submit(1).result(timeout=30)
    server.stop()
    server.stop()                      # second stop: no-op, no raise
    server.stop_metrics_http()
    server.stop_metrics_http()


def test_scrape_racing_shutdown_answers_500(monkeypatch):
    server = ASAServer(_cfg())
    port = server.serve_metrics_http(port=0)
    url = f"http://127.0.0.1:{port}/stats"
    assert urllib.request.urlopen(url, timeout=5).status == 200
    # simulate the race: the stats view tears down mid-scrape
    monkeypatch.setattr(
        ASAServer, "stats",
        property(lambda self: (_ for _ in ()).throw(
            RuntimeError("teardown race"))))
    try:
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(url, timeout=5)
        assert exc.value.code == 500
    except RemoteDisconnected:  # pragma: no cover
        pytest.fail("handler died on the socket instead of answering 500")
    finally:
        monkeypatch.undo()
        server.stop_metrics_http()


# --------------------------------------------------------------- property
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10**9))
def test_every_future_resolves_under_chaos(seed):
    """Random submit/observe/evict interleavings against a seeded chaos
    schedule (step faults + a crash + a burst), served by a supervisor:
    every submitted future resolves — a Decision or a typed error — and
    the surviving checkpoint restores bitwise."""
    rng = random.Random(seed)
    with tempfile.TemporaryDirectory(prefix="chaos_prop_") as tmp:
        _chaos_property_body(seed, rng, Path(tmp))


def _chaos_property_body(seed, rng, tmp):
    cfg = ServeConfig(n_slots=6, batch_size=4,
                      checkpoint_dir=str(tmp / "ckpt"),
                      checkpoint_every=2, max_queue=64,
                      tenant_ttl_s=5.0)
    events = [schaos.step_exception(rng.randrange(1, 6)),
              schaos.crash(rng.randrange(1, 6))]
    if rng.random() < 0.5:
        burst_b = rng.randrange(1, 6)
        if all(e.batch != burst_b or e.kind != "queue_burst"
               for e in events):
            events.append(schaos.queue_burst(burst_b, 8))
    inj = schaos.ChaosInjector(schaos.ChaosSchedule(tuple(events)),
                               seed=seed)
    sup = ServeSupervisor(cfg, chaos=inj)
    futs = []
    sup.start()
    try:
        for _ in range(rng.randrange(10, 30)):
            op = rng.random()
            tenant = rng.randrange(10)
            if op < 0.5:
                futs.append(sup.submit(tenant))
            elif op < 0.8:
                futs.append(sup.submit(
                    tenant, observed_wait=rng.uniform(10.0, 4000.0)))
            else:
                try:
                    sup.server.evict(tenant)
                except (KeyError, ServerCrashed):
                    pass               # unknown tenant / mid-restart
            if rng.random() < 0.3:
                time.sleep(0.002)
        deadline = time.monotonic() + 120
        for f in futs + list(inj.burst_futures):
            remaining = deadline - time.monotonic()
            assert remaining > 0, "futures still pending at deadline"
            err = f.exception(timeout=remaining)
            assert err is None or isinstance(err, RuntimeError), \
                f"untyped error {err!r}"
    finally:
        sup.stop()
    step = CKPT.latest_step(cfg.checkpoint_dir, verified=True)
    if step is not None:
        a = ASAServer.restore(cfg, step=step, verified=True)
        b = ASAServer.restore(cfg, step=step, verified=True)
        assert _probe(a, range(10)) == _probe(b, range(10))
        np.testing.assert_array_equal(np.asarray(a._table.log_p),
                                      np.asarray(b._table.log_p))
