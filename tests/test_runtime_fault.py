"""runtime.fault schedules + runtime.elastic resize plans (host-side).

The xsim engine consumes these as arrays (tests/test_xsim_faults.py);
here the host-side data model itself is pinned: validation, sorting,
slot padding/overflow, the resize→schedule mapping, and the heartbeat
tracker's expiry/recovery ordering edges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime import fault
from repro.runtime.elastic import resize_schedule
from repro.runtime.fault import (FAULT_DRAIN, FAULT_FAIL, FAULT_GROW,
                                 CapacityEvent, FaultSchedule,
                                 HeartbeatTracker, StragglerPolicy)

# ------------------------------------------------------- CapacityEvent


def test_capacity_event_validation():
    with pytest.raises(ValueError, match="finite"):
        CapacityEvent(-1.0, 0.5, FAULT_FAIL)
    with pytest.raises(ValueError, match="finite"):
        CapacityEvent(np.inf, 0.5, FAULT_FAIL)
    with pytest.raises(ValueError, match="> 0"):
        CapacityEvent(10.0, 0.0, FAULT_FAIL)
    with pytest.raises(ValueError, match="> 0"):
        CapacityEvent(10.0, -0.2, FAULT_GROW)
    with pytest.raises(ValueError, match="unknown fault kind"):
        CapacityEvent(10.0, 0.5, 7)
    # a shrink can never exceed the whole machine; a grow can double it
    with pytest.raises(ValueError, match="<= 1"):
        fault.fail(10.0, 1.5)
    with pytest.raises(ValueError, match="<= 1"):
        fault.drain(10.0, 1.01)
    assert fault.grow(10.0, 1.5).frac == 1.5


def test_constructors_tag_kinds():
    assert fault.fail(1.0, 0.5).kind == FAULT_FAIL
    assert fault.drain(1.0, 0.5).kind == FAULT_DRAIN
    assert fault.grow(1.0, 0.5).kind == FAULT_GROW


# ------------------------------------------------------- FaultSchedule


def test_schedule_sorts_by_time_and_len():
    s = FaultSchedule((fault.grow(300.0, 0.5), fault.fail(100.0, 0.25),
                       fault.drain(200.0, 0.25)))
    assert len(s) == 3
    assert [e.t for e in s.events] == [100.0, 200.0, 300.0]
    assert [e.kind for e in s.events] == [FAULT_FAIL, FAULT_DRAIN,
                                          FAULT_GROW]
    assert len(FaultSchedule()) == 0


def test_as_arrays_pads_rounds_and_overflows():
    s = FaultSchedule((fault.fail(100.0, 0.25), fault.grow(200.0, 0.25)))
    t, c, k = s.as_arrays(4, total_cores=670.0)
    np.testing.assert_array_equal(t, [100.0, 200.0, np.inf, np.inf])
    # deltas are round(frac · ORIGINAL total): integer-exact core counts
    np.testing.assert_array_equal(c, [168.0, 168.0, 0.0, 0.0])
    np.testing.assert_array_equal(k, [FAULT_FAIL, FAULT_GROW, 0, 0])
    assert t.dtype == np.float32 and c.dtype == np.float32
    assert k.dtype == np.int32
    with pytest.raises(ValueError, match="fault events > 1 slots"):
        s.as_arrays(1, total_cores=670.0)
    # the empty schedule is all padding — the engine's no-op encoding
    t0, c0, k0 = FaultSchedule().as_arrays(2, total_cores=64.0)
    assert np.all(np.isinf(t0)) and np.all(c0 == 0) and np.all(k0 == 0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.floats(8.0, 4096.0))
def test_as_arrays_roundtrip_property(seed, n, total):
    """Random schedules: times come back sorted ascending with +inf
    padding, deltas are integral and positive for every real slot."""
    rng = np.random.default_rng(seed)
    kinds = (fault.fail, fault.drain, fault.grow)
    evs = tuple(kinds[int(rng.integers(0, 3))](
        float(rng.uniform(0.0, 1e5)), float(rng.uniform(0.05, 1.0)))
        for _ in range(n))
    sched = FaultSchedule(evs)
    t, c, k = sched.as_arrays(n + 2, total)
    assert np.all(np.diff(t[:n]) >= 0.0)          # sorted
    assert np.all(np.isinf(t[n:]))                # padded
    assert np.all(c[:n] == np.round(c[:n]))       # integer-exact cores
    assert np.all(c[:n] >= 0.0)
    assert set(k[:n]) <= {FAULT_FAIL, FAULT_DRAIN, FAULT_GROW}


# ------------------------------------------------------ resize_schedule


def test_resize_schedule_maps_deltas():
    s = resize_schedule([(100.0, -0.3), (200.0, +0.3)])
    assert [e.kind for e in s.events] == [FAULT_DRAIN, FAULT_GROW]
    assert [e.frac for e in s.events] == [0.3, 0.3]
    p = resize_schedule([(100.0, -0.3), (200.0, +0.3)], preempt=True)
    assert [e.kind for e in p.events] == [FAULT_FAIL, FAULT_GROW]
    with pytest.raises(ValueError, match="zero-delta"):
        resize_schedule([(100.0, 0.0)])


# ------------------------------------- heartbeat expiry/recovery edges


def test_heartbeat_recovery_and_refailure_ordering():
    """A worker that misses its deadline, beats again, then goes silent
    must be reported failed TWICE, in order — recovery re-arms the
    failure edge instead of latching the worker dead."""
    hb = HeartbeatTracker(timeout_s=60.0)
    seen = []
    hb.on_failure.append(seen.append)
    hb.register(1, now=0.0)
    hb.register(2, now=0.0)
    hb.beat(2, now=50.0)
    assert hb.sweep(now=61.0) == [1]              # 1 expired, 2 beat
    assert hb.healthy_count() == 1
    # a repeated sweep must NOT re-report the already-failed worker
    assert hb.sweep(now=65.0) == []
    hb.beat(1, now=70.0)                          # 1 recovers
    assert hb.healthy_count() == 2
    assert hb.sweep(now=90.0) == []
    assert hb.sweep(now=200.0) == [1, 2]          # both silent again
    assert seen == [1, 1, 2]
    # a beat for an unregistered worker is a no-op, not a registration
    hb.beat(99, now=0.0)
    assert 99 not in hb.workers


def test_heartbeat_beat_exactly_at_deadline_survives():
    """The deadline is strict (> timeout): a beat landing exactly at
    last + timeout keeps the worker healthy."""
    hb = HeartbeatTracker(timeout_s=60.0)
    hb.register(1, now=0.0)
    assert hb.sweep(now=60.0) == []               # boundary: not yet late
    assert hb.sweep(now=60.001) == [1]


def test_straggler_policy_min_samples_and_floor():
    p = StragglerPolicy(quantile=0.5, factor=2.0, min_samples=3,
                        floor_s=10.0)
    assert p.deadline([1.0, 2.0]) is None         # below min_samples
    assert p.deadline([1.0, 1.0, 1.0]) == 10.0    # floor wins over 2·q
    assert p.deadline([100.0, 100.0, 100.0]) == 200.0
