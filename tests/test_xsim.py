"""repro.xsim: cross-validation vs QueueSim + scheduling invariants.

The cross-validation tests snapshot a live event-driven QueueSim into an
xsim job table and run both engines from the identical machine state —
waits and makespans must agree (exactly, for these deterministic
no-new-arrival scenarios; the assertions allow a small tolerance for the
bounded-backfill approximation). Both engines now learn *within* the
run: the ASA/ASA-Naive differential tests seed identical Algorithm-1
states on both sides and require the sampled prediction sequences to
match action-for-action through the whole scenario.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import asa
from repro.core.bins import make_bins
from repro.core.losses import zero_one
from repro.core.regret import empirical_regret, theorem1_bound
from repro.sched.centers import CenterProfile
from repro.sched.queue_sim import QueueSim
from repro.sched.strategies import (ASAEstimator, pilot_waste_cs, run_asa,
                                    run_bigjob, run_per_stage, run_pilot)
from repro.sched.workflows import BLAST, MONTAGE, STATISTICS
from repro.xsim import backfill, compare, events, policies
from repro.xsim import state as X
from repro.xsim.grid import (XSimConfig, make_grid, run_grid, stage_waits,
                             warm_fleet)
from repro.xsim.state import add_job, empty_table, freeze

TINY = CenterProfile(
    name="tiny", nodes=8, cores_per_node=4,
    bg_arrival_rate=1 / 200.0, bg_cores_mean=1.5, bg_cores_sigma=0.8,
    bg_duration_mean_s=7.0, bg_duration_sigma=0.8, bg_initial_backlog=12,
    bg_burst_mean=1.0, scales=(8,))

REL_TOL = 0.02  # bounded-backfill divergence allowance


def _mirrored(seed):
    """A warmed QueueSim (no further arrivals) + its xsim snapshot."""
    sim = QueueSim(TINY, seed=seed, bg_horizon=0.0)
    sim.run_until(600.0)
    table, row = compare.scenario_from_queue_sim(sim, max_jobs=64)
    return sim, table, row


def _close(a, b):
    assert a == pytest.approx(b, rel=REL_TOL, abs=5.0), (a, b)


# ------------------------------------------------------- cross-validation
@pytest.mark.parametrize("wf", [BLAST, STATISTICS])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bigjob_matches_queue_sim(wf, seed):
    sim, table, row = _mirrored(seed)   # snapshot BEFORE the ref run
    free = compare.queue_sim_free_cores(sim)
    ref = run_bigjob(sim, wf, 8, "tiny")

    policies.add_workflow(table, row, wf, 8, X.BIGJOB, t0=600.0)
    st = freeze(table, total_cores=TINY.total_cores, free_cores=free,
                now=600.0, policy=X.BIGJOB, t0=600.0)
    fin = events.simulate(st, n_steps=160)
    m = compare.metrics(fin)
    _close(float(m["twt_s"]), ref.twt_s)
    _close(float(m["makespan_s"]), ref.makespan_s)
    _close(float(m["core_hours"]), ref.core_hours)


@pytest.mark.parametrize("wf", [BLAST, STATISTICS, MONTAGE])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_per_stage_matches_queue_sim(wf, seed):
    sim, table, row = _mirrored(seed)   # snapshot BEFORE the ref run
    free = compare.queue_sim_free_cores(sim)
    ref = run_per_stage(sim, wf, 8, "tiny")

    policies.add_workflow(table, row, wf, 8, X.PER_STAGE, t0=600.0)
    st = freeze(table, total_cores=TINY.total_cores, free_cores=free,
                now=600.0, policy=X.PER_STAGE, t0=600.0)
    fin = events.simulate(st, n_steps=220)
    m = compare.metrics(fin)
    _close(float(m["twt_s"]), ref.twt_s)
    _close(float(m["makespan_s"]), ref.makespan_s)
    # utilization sanity on the shared background
    assert 0.0 < float(m["utilization"]) <= 1.0


@pytest.mark.parametrize("wf", [STATISTICS, MONTAGE])
@pytest.mark.parametrize("seed", [0, 2, 3])
@pytest.mark.parametrize("use_deps", [True, False])
def test_asa_matches_queue_sim(wf, seed, use_deps):
    """ASA (and §4.5 ASA-Naive) differential cross-validation.

    Both engines start from the *identical* machine snapshot AND the
    identical Algorithm-1 estimator state; both learn within the run.
    Perceived waits, makespans, overhead hours, miss counts and the full
    sampled prediction sequence must agree — the estimator's PRNG is
    consumed call-for-call in the same order on both sides.
    """
    sim, table, row = _mirrored(seed)   # snapshot BEFORE the ref run
    free = compare.queue_sim_free_cores(sim)
    ref = run_asa(sim, wf, 8, "tiny", ASAEstimator(seed=seed + 17),
                  use_dependencies=use_deps)

    pol = X.ASA if use_deps else X.ASA_NAIVE
    policies.add_workflow(table, row, wf, 8, pol, t0=600.0)
    st = freeze(table, total_cores=TINY.total_cores, free_cores=free,
                now=600.0, policy=pol, t0=600.0,
                est=asa.init(53, jax.random.PRNGKey(seed + 17)))
    fin = events.simulate(st, n_steps=300)
    m = compare.metrics(fin)
    _close(float(m["twt_s"]), ref.twt_s)
    _close(float(m["makespan_s"]), ref.makespan_s)
    assert float(m["oh_hours"]) == pytest.approx(ref.oh_hours, abs=1e-3)
    assert int(m["misses"]) == ref.misses
    if use_deps:
        assert float(m["oh_hours"]) == 0.0  # dependency-ASA never idles
    # live-sampled cascade estimates match the event-driven sequence
    # exactly (stage 0's a_0 is not recorded in RunMetrics.pred_waits)
    preds = np.asarray(fin.pred_wait)[np.asarray(fin.is_wf)]
    np.testing.assert_allclose(preds[1:len(ref.pred_waits) + 1],
                               ref.pred_waits)
    # within-run learning really ran inside the scan: one tuned update
    # (2 estimator events) per settled stage start
    assert int(fin.est.t) >= 2 * len(wf.stages)


@pytest.mark.parametrize("wf", [BLAST, STATISTICS])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pilot_matches_queue_sim(wf, seed):
    """Pilot-job differential: one peak-width allocation whose walltime
    adds the pilot bootstrap + per-stage dispatch latency on top of the
    serialized stage work. Both engines model the identical single-job
    shape, so the match is exact (same machine snapshot, no divergence
    sources) — the tolerance is the shared ``_close`` formality."""
    sim, table, row = _mirrored(seed)   # snapshot BEFORE the ref run
    free = compare.queue_sim_free_cores(sim)
    ref = run_pilot(sim, wf, 8, "tiny")

    policies.add_workflow(table, row, wf, 8, X.PILOT, t0=600.0)
    st = freeze(table, total_cores=TINY.total_cores, free_cores=free,
                now=600.0, policy=X.PILOT, t0=600.0,
                pilot_waste_cs=pilot_waste_cs(wf, 8))
    fin = events.simulate(st, n_steps=160)
    m = compare.metrics(fin)
    _close(float(m["twt_s"]), ref.twt_s)
    _close(float(m["makespan_s"]), ref.makespan_s)
    _close(float(m["core_hours"]), ref.core_hours)
    # the over-allocation waste is charged as OH once the pilot runs
    assert float(m["oh_hours"]) == pytest.approx(ref.oh_hours, rel=1e-5)
    assert float(m["oh_hours"]) > 0.0
    assert int(m["wf_done"]) == int(m["wf_total"]) == 1


def test_naive_cancel_resubmit_exercised():
    """Across the differential seeds the naive path must actually cancel:
    at least one mirrored scenario takes the CANCELLED→resubmit edge and
    charges cancel-latency OH (montage seed 2 takes seven misses)."""
    total_miss, total_oh = 0, 0.0
    for seed in (0, 2, 3):
        sim, table, row = _mirrored(seed)
        free = compare.queue_sim_free_cores(sim)
        policies.add_workflow(table, row, MONTAGE, 8, X.ASA_NAIVE, t0=600.0)
        st = freeze(table, total_cores=TINY.total_cores, free_cores=free,
                    now=600.0, policy=X.ASA_NAIVE, t0=600.0,
                    est=asa.init(53, jax.random.PRNGKey(seed + 17)))
        fin = events.simulate(st, n_steps=300)
        m = compare.metrics(fin)
        total_miss += int(m["misses"])
        total_oh += float(m["oh_hours"])
        assert int(m["wf_done"]) == int(m["wf_total"])  # resubmits finish
    assert total_miss >= 3
    assert total_oh > 0.0


# ------------------------------------------------------------ invariants
def _bare(total=100.0, free=100.0, max_jobs=16, policy=X.BIGJOB):
    return empty_table(max_jobs), dict(total_cores=total, free_cores=free,
                                       policy=policy)


def test_never_over_allocates():
    """min_free stays ≥ 0 across a busy random scenario sweep."""
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=1800.0)
    grid = make_grid(cfg, n_seeds=2, shrink=1 / 128.0,
                     workflows=("montage",))
    final, m = run_grid(grid)
    assert float(jnp.min(final.min_free)) >= 0.0
    # conservation at the end of the sweep
    running = np.asarray(final.status) == X.RUNNING
    used = np.sum(np.where(running, np.asarray(final.cores), 0.0), axis=1)
    np.testing.assert_allclose(used + np.asarray(final.free),
                               np.asarray(final.total), rtol=1e-5)


def test_fcfs_order_respected():
    """Equal-width jobs start in submission order."""
    t, kw = _bare()
    for i, sub in enumerate((0.0, 10.0, 20.0, 30.0)):
        add_job(t, i, cores=60, duration=100.0, submit=sub, status=X.PENDING)
    st = freeze(t, **kw)
    fin = events.simulate(st, n_steps=30)
    starts = np.asarray(fin.start[:4])
    assert np.all(np.diff(starts) > 0)  # 60-core jobs serialize, in order


def test_backfill_fills_without_delaying_head():
    """A short narrow job backfills ahead of a blocked wide head job,
    and the head still starts exactly at its reservation (shadow) time."""
    t, kw = _bare(free=40.0)
    # 60 cores busy until t=1000
    add_job(t, 0, cores=60, duration=1000.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=1000.0)
    t["start"][0] = 0.0
    t["end"][0] = 1000.0
    # head: wants 80 cores -> must wait for t=1000 (shadow)
    add_job(t, 1, cores=80, duration=500.0, submit=10.0, status=X.PENDING)
    # backfill candidate: 20 cores, drains before the shadow
    add_job(t, 2, cores=20, duration=400.0, submit=20.0, status=X.PENDING)
    # NOT backfillable: 20 cores but too long (would delay nothing core-wise
    # but exceeds the shadow window and the spare at shadow is 100-80=20...
    # cores 30 > spare 20 and duration crosses the shadow)
    add_job(t, 3, cores=30, duration=5000.0, submit=30.0, status=X.PENDING)
    st = freeze(t, **kw)
    fin = events.simulate(st, n_steps=30)
    start = np.asarray(fin.start)
    assert start[2] == 20.0          # backfilled immediately at submit
    assert start[1] == 1000.0        # head starts exactly at shadow time
    assert start[3] >= 1000.0        # long job could not jump the head


def test_backfill_in_spare_cores_of_reservation():
    """A long narrow job may still backfill if it fits the reservation's
    spare cores (EASY 'extra' rule)."""
    t, kw = _bare(free=40.0)
    add_job(t, 0, cores=60, duration=1000.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=1000.0)
    t["start"][0] = 0.0
    t["end"][0] = 1000.0
    add_job(t, 1, cores=80, duration=500.0, submit=10.0, status=X.PENDING)
    # 15 cores <= extra (100-80=20): backfills despite 5000s duration
    add_job(t, 2, cores=15, duration=5000.0, submit=20.0, status=X.PENDING)
    st = freeze(t, **kw)
    fin = events.simulate(st, n_steps=30)
    assert float(fin.start[2]) == 20.0
    assert float(fin.start[1]) == 1000.0


def test_dependency_blocks_start():
    t, kw = _bare()
    add_job(t, 0, cores=10, duration=500.0, submit=0.0, status=X.PENDING)
    add_job(t, 1, cores=10, duration=100.0, submit=0.0, status=X.PENDING,
            start_dep=0)
    st = freeze(t, **kw)
    fin = events.simulate(st, n_steps=30)
    assert float(fin.start[1]) >= float(fin.end[0]) == 500.0


def test_pallas_reservation_matches_reference():
    """Sorted jnp path and sorted Pallas kernel == the O(n²) reference,
    exactly — including duplicated end times (tie runs)."""
    rng = np.random.default_rng(3)
    B, N = 3, 128
    ends = jnp.asarray(rng.uniform(0, 1e4, (B, N)), jnp.float32)
    ends = ends.at[:, ::4].set(5000.0)          # force ties
    cores = jnp.asarray(rng.integers(1, 50, (B, N)), jnp.float32)
    running = jnp.asarray(rng.random((B, N)) < 0.5)
    ref = jax.vmap(backfill._freed_math)(ends, cores, running)
    srt = jax.vmap(backfill._freed_sorted)(ends, cores, running)
    ker = backfill.freed_matrix(ends, cores, running, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(srt))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


def test_chunked_simulate_respects_step_budget():
    """Chunked and unchunked simulate are bitwise identical in BOTH
    regimes: drained (extra chunk steps are no-ops) and truncated (the
    while_loop runs ⌊n_steps/chunk⌋ chunks plus a static remainder scan,
    never granting more than exactly ``n_steps`` steps — a budget that
    is not a chunk multiple must not be rounded up)."""
    t, kw = _bare()
    for i, sub in enumerate((0.0, 500.0, 1000.0, 1500.0, 2000.0)):
        add_job(t, i, cores=60, duration=100.0, submit=sub,
                status=X.PENDING)
    st = freeze(t, **kw)
    # truncation regime: 3 steps of budget, chunk default 8, events left
    a = events.simulate(st, n_steps=3, chunk_steps=0)
    b = events.simulate(st, n_steps=3)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert int(b.steps) == 3
    # drained regime: every chunk size reproduces the static scan
    c = events.simulate(st, n_steps=40, chunk_steps=0)
    for k in (1, 8, 64):
        d = events.simulate(st, n_steps=40, chunk_steps=k)
        for x, y in zip(jax.tree.leaves(c), jax.tree.leaves(d)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_freed_mode_ref_n2_end_to_end():
    """The sorted default and the retained O(n²) reference drive bitwise
    identical simulations (the reservation rework is numerically
    invisible on the integer-core tables the engine uses)."""
    t, kw = _bare()
    policies.add_workflow(t, 0, MONTAGE, 28, X.PER_STAGE, t0=0.0)
    st = freeze(t, policy=X.PER_STAGE, total_cores=100.0, free_cores=100.0)
    a = events.simulate(st, n_steps=48)
    b = events.simulate(st, n_steps=48, freed_mode="ref_n2")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError, match="freed mode"):
        events.simulate(st, n_steps=8, freed_mode="bogus")


def test_pallas_freed_mode_end_to_end():
    t, kw = _bare()
    policies.add_workflow(t, 0, STATISTICS, 28, X.PER_STAGE, t0=0.0)
    st = freeze(t, policy=X.PER_STAGE, total_cores=100.0, free_cores=100.0)
    a = events.simulate(st, n_steps=40)
    b = events.simulate(st, n_steps=40, freed_mode="interpret")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- fleet sweep + ordering
def test_vmapped_sweep_and_table1_ordering():
    """One jitted vmapped program over the full grid (all five queue
    policies, learning within each scan) reproduces the paper's
    qualitative Table-1 ordering:
      CH(asa) == CH(per_stage) < CH(bigjob),
      TWT(asa) best, makespan(asa) < makespan(per_stage),
    the §4.5 Naive/Dependency trade-off (ASA-Naive pays OH > 0 and loses
    perceived waiting time to dependency-ASA), and the pilot-job
    trade-off: a pilot queues ONCE at peak width (so its queue wait is
    BigJob's, within reach of Per-Stage's summed stage waits) but pays
    BigJob-like packing waste plus bootstrap/dispatch overhead —
    CH(pilot) == CH(asa) + OH(pilot), mirroring ASA-Naive's identity."""
    cfg = XSimConfig(n_warm=24, n_backlog=16, n_arrivals=24, max_stages=9,
                     t0=3600.0)
    grid = make_grid(cfg, n_seeds=2, shrink=1 / 64.0,
                     policy_ids=(0, 1, 2, 3, 5))
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet, grid, rounds=3)
    final, m = run_grid(grid, fleet, pred_seed=7)
    m = {k: np.asarray(v) for k, v in m.items()}

    # every scenario finished inside the step budget
    assert np.all(m["wf_done"] == m["wf_total"])
    assert np.all(np.isfinite(m["makespan_s"]))

    by = {}
    for i, lab in enumerate(grid.labels):
        by.setdefault(lab["strategy"], []).append(i)
    mean = {s: {k: float(np.mean(m[k][idx])) for k in
                ("twt_s", "makespan_s", "core_hours", "oh_hours")}
            for s, idx in by.items()}

    # CH(asa) == CH(per_stage) < CH(bigjob)  (paper: BigJob +53% CH)
    assert mean["asa"]["core_hours"] == pytest.approx(
        mean["per_stage"]["core_hours"], rel=1e-6)
    assert mean["bigjob"]["core_hours"] > 1.2 * mean["asa"]["core_hours"]
    # ASA's perceived waiting time is the best of the strategies
    assert mean["asa"]["twt_s"] < mean["per_stage"]["twt_s"]
    assert mean["asa"]["twt_s"] < mean["bigjob"]["twt_s"]
    # ASA hides stage waits behind execution: beats Per-Stage on makespan
    assert mean["asa"]["makespan_s"] < mean["per_stage"]["makespan_s"]
    # §4.5 trade-off: without dependency support ASA-Naive mispredicts
    # into idle/cancel overhead and a worse perceived wait than ASA
    assert mean["asa_naive"]["oh_hours"] > 0.0
    assert mean["asa_naive"]["twt_s"] > mean["asa"]["twt_s"]
    assert mean["asa_naive"]["core_hours"] == pytest.approx(
        mean["asa"]["core_hours"] + mean["asa_naive"]["oh_hours"], rel=1e-5)
    # pilot queue wait: one peak-width submission at t0 — identical queue
    # position to BigJob's (same width, same instant), and within a small
    # slack of Per-Stage's summed narrow-stage waits
    assert mean["bigjob"]["twt_s"] <= mean["pilot"]["twt_s"] + 1e-3
    assert mean["pilot"]["twt_s"] <= 1.1 * mean["per_stage"]["twt_s"]
    # ...but the pilot pays for it: bootstrap + dispatch stretch the
    # makespan past BigJob's, the over-allocation is charged as OH, and
    # the core-hours identity mirrors ASA-Naive's
    assert mean["pilot"]["makespan_s"] > mean["bigjob"]["makespan_s"]
    assert mean["pilot"]["oh_hours"] > 0.0
    assert mean["pilot"]["core_hours"] == pytest.approx(
        mean["asa"]["core_hours"] + mean["pilot"]["oh_hours"], rel=1e-5)
    assert mean["pilot"]["core_hours"] > mean["bigjob"]["core_hours"]
    # the other strategies never accrue OH
    for strat in ("bigjob", "per_stage", "asa"):
        assert mean[strat]["oh_hours"] == 0.0


def test_within_run_learning_regret_convergence():
    """Theorem-1 regression for in-scan learning (paper Appendix A).

    A 3-round warm-started sweep observes a per-geometry wait sequence;
    on that sequence the adaptive tuned estimator must (a) actually have
    learned inside the scan (estimator case-counts advanced for ASA
    scenarios only), (b) keep empirical regret under the Theorem-1 bound,
    and (c) be no worse than the frozen-MAP baseline — the prediction
    rule the engine used before within-run learning landed.
    """
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=1800.0)
    grid = make_grid(cfg, n_seeds=4, shrink=1 / 64.0,
                     workflows=("statistics",), policy_ids=(1, 2))
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet, grid, rounds=3)
    final, m = run_grid(grid, fleet)

    # (a) the scan carried the estimator: only ASA scenarios learned
    init_t = np.asarray(fleet.t)[grid.geo_idx]
    est_t = np.asarray(final.est.t)
    strat = np.array([lab["strategy"] for lab in grid.labels])
    is_asa = strat == "asa"
    assert np.all(est_t[is_asa] > init_t[is_asa])
    assert np.all(est_t[~is_asa] == init_t[~is_asa])

    # (b) + (c): replay the full 3-round observation sequence per geometry.
    # The warm rounds + final sweep are the sequence the learner actually
    # saw; the "frozen" baseline predicts with the cold initial MAP for
    # the whole campaign — exactly what predictions looked like before
    # within-run learning landed, on a fresh fleet.
    n_geo = int(grid.geo_idx.max()) + 1
    seqs: list[list[float]] = [[] for _ in range(n_geo)]
    replay_fleet = policies.init_fleet(n_geo)
    for r in range(3):
        rf, _ = run_grid(grid, replay_fleet, pred_seed=100 + r)
        w_r, v_r = stage_waits(rf, cfg)
        for g in range(n_geo):
            sel = (grid.geo_idx == g) & is_asa
            seqs[g].extend(w_r[sel][v_r[sel]].tolist())
        W = np.zeros((n_geo, 8), np.float32)
        V = np.zeros((n_geo, 8), bool)
        for g in range(n_geo):
            w = w_r[(grid.geo_idx == g) & is_asa, 0]
            w = w[v_r[(grid.geo_idx == g) & is_asa, 0]][:8]
            W[g, :len(w)] = w
            V[g, :len(w)] = True
        replay_fleet = policies.update_fleet(replay_fleet, jnp.asarray(W),
                                             jnp.asarray(V))
    bins = jnp.asarray(make_bins(53), jnp.float32)
    cold = asa.init(53, jax.random.PRNGKey(0))
    a_frozen = int(np.argmax(np.asarray(cold.log_p)))  # cold MAP, fixed
    g_one = jnp.float32(1.0)
    total_adaptive = total_frozen = 0.0
    for g in range(n_geo):
        ws = seqs[g]
        if not ws:
            continue
        L = np.stack([np.asarray(zero_one(bins, jnp.float32(max(w, 1.0))))
                      for w in ws])
        state = cold
        eta0 = int(state.rounds)
        chosen = []
        for lv in L:
            # live-MAP decision (the fleet-sweep prediction rule), tuned
            # §4.5 learning from the observed wait — as the scan hooks do
            chosen.append(lv[int(np.argmax(np.asarray(state.log_p)))])
            state, _ = asa.step(state, jnp.asarray(lv), g_one,
                                policy="tuned")
        r_adaptive = empirical_regret(np.asarray(chosen), L)
        assert r_adaptive <= theorem1_bound(
            len(chosen), 53, int(state.rounds) - eta0)
        total_adaptive += r_adaptive
        total_frozen += empirical_regret(L[:, a_frozen], L)
    # learning while running beats the frozen cold-MAP predictor
    assert total_adaptive <= total_frozen


def test_stage_waits_and_fleet_learning():
    """warm_fleet moves each geometry's MAP estimate toward its observed
    first-stage wait decade (the §4.3 cross-run persistence loop)."""
    cfg = XSimConfig(n_warm=16, n_backlog=12, n_arrivals=16, max_stages=9,
                     t0=1800.0)
    grid = make_grid(cfg, n_seeds=2, shrink=1 / 64.0,
                     workflows=("statistics",))
    fleet0 = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet0, grid, rounds=2)
    # distributions moved away from uniform
    assert not np.allclose(np.asarray(fleet.log_p), np.asarray(fleet0.log_p))
    final, _ = run_grid(grid, fleet)
    waits, valid = stage_waits(final, cfg)
    assert waits.shape == (grid.n, cfg.max_stages)
    assert valid.any()
