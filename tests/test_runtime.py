"""Runtime layer: checkpoint roundtrip, pool, fault, elastic, campaign."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as CKPT
from repro.runtime.campaign import CampaignScheduler, CampaignStage
from repro.runtime.elastic import reshard_plan
from repro.runtime.fault import (HeartbeatTracker, StragglerMitigator,
                                 StragglerPolicy)
from repro.runtime.pool import ResourcePool


def _tree(key):
    return {
        "a": jax.random.normal(key, (8, 16)),
        "nested": {"b": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                   "c": jnp.float32(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    CKPT.save(t, tmp_path, 7)
    assert CKPT.latest_step(tmp_path) == 7
    r = CKPT.restore(t, tmp_path, 7)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    th = CKPT.save_async(t, tmp_path, 1)
    th.join()
    CKPT.save(t, tmp_path, 5)
    assert CKPT.latest_step(tmp_path) == 5


def test_checkpoint_atomicity(tmp_path):
    """A directory without manifest.json is never considered restorable."""
    d = tmp_path / "step_9"
    d.mkdir(parents=True)
    (d / "a.bin").write_bytes(b"garbage")
    assert CKPT.latest_step(tmp_path) is None


def test_pool_claim_release_revoke():
    pool = ResourcePool()
    a1 = pool.add_allocation(4)
    pool.add_allocation(4)
    assert pool.available() == 8
    c = pool.claim(6)  # spans both allocations
    assert c is not None and pool.available() == 2
    revoked = []
    pool.on_revoke.append(lambda cl: revoked.append(cl.id))
    pool.remove_allocation(a1.id)
    assert revoked == [c.id]
    # the revoked spanning claim hands its slices back to the surviving
    # allocation — full capacity, not a leak (see test_pool_properties)
    assert pool.available() == 4
    assert pool.check_invariants() == []
    assert pool.claim(100) is None


def test_heartbeat_failure_detection():
    hb = HeartbeatTracker(timeout_s=10.0)
    hb.register(1, 0.0)
    hb.register(2, 0.0)
    hb.beat(1, 8.0)
    failed = hb.sweep(12.0)
    assert failed == [2]
    assert hb.healthy_count() == 1


def test_straggler_mitigation():
    sm = StragglerMitigator(StragglerPolicy(quantile=0.5, factor=2.0,
                                            min_samples=3))
    for i in range(5):
        sm.start(i, 0.0)
        sm.finish(i, 10.0)
    sm.start(99, 0.0)
    assert sm.stragglers(15.0) == []     # deadline = 10*2 = 20
    assert sm.stragglers(25.0) == [99]


def test_reshard_plan_reports_moves():
    from repro.parallel.sharding import ShardingRules

    class FakeMesh:
        def __init__(self, shape_map):
            self.shape = shape_map
            self.axis_names = tuple(shape_map)
    r16 = ShardingRules(FakeMesh({"data": 16, "model": 16}))
    r8 = ShardingRules(FakeMesh({"data": 8, "model": 16}))
    params = {"mlp": {"w_gate": jnp.zeros((4096, 16384))}}
    plan = reshard_plan(params, r16, r8)
    assert len(plan) == 1
    assert plan[0].bytes_total == 4096 * 16384 * 4


def test_campaign_overlaps_waits():
    """ASA campaign scheduling hides queue waits behind running stages."""
    from repro.sched.centers import UPPMAX
    from repro.sched.queue_sim import QueueSim
    from repro.sched.strategies import ASAEstimator

    est = ASAEstimator(seed=3)
    stages = [CampaignStage(f"s{i}", 160, 3000.0) for i in range(4)]
    # warm-up campaign (state persists, §4.3)
    sched0 = CampaignScheduler(QueueSim(UPPMAX, seed=11), est)
    sched0.sim.run_until(3600)
    sched0.run(stages)
    # measured campaign
    sim = QueueSim(UPPMAX, seed=12)
    sim.run_until(3600)
    rep = CampaignScheduler(sim, est).run(stages)
    waits = [o.real_wait_s for o in rep.outcomes]
    pwts = [o.perceived_wait_s for o in rep.outcomes[1:]]
    # later-stage perceived waits must be far below the raw queue waits
    assert sum(pwts) < 0.5 * sum(waits[1:])
    assert rep.makespan_s > 0
