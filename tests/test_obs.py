"""Observability layer (repro.obs): ring buffer, metrics, export, schema.

Pins the load-bearing contracts of the tracing subsystem:

- ``trace=None`` statically elides every append — the disabled path is
  the pre-observability program, bit for bit (the shard_map variants of
  this live in tests/test_xsim_sharded.py);
- the ring overflows by dropping the OLDEST events deterministically,
  flags it, and never corrupts surviving events;
- the Chrome trace export round-trips the ring accounting and the
  per-scenario ``steps`` counters;
- the differential replay: per-stage perceived waits reconstructed from
  the trace alone match ``compare.metrics``'s ``twt_s`` exactly (f32
  equality) on the 12 mirrored QueueSim scenarios;
- the telemetry schema rejects malformed records by NAME, and
  bench_gate turns them into named failures, not KeyErrors.
"""

from __future__ import annotations

import json
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry
from repro.obs import trace as T
from repro.xsim import policies
from repro.xsim.grid import XSimConfig, make_grid, run_grid
from repro.xsim.state import ASA, ASA_NAIVE, BIGJOB, PER_STAGE, QUEUED, RUNNING


def tiny_cfg(**kw) -> XSimConfig:
    return XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                      t0=1800.0, **kw)


def tiny_grid(cfg, policy_ids=(BIGJOB, PER_STAGE, ASA, ASA_NAIVE),
              n_seeds=1):
    # hpc2n has 3 paper scales → B = 3 · |policies| · n_seeds = 12: the
    # mirrored QueueSim comparison set
    return make_grid(cfg, center_names=("hpc2n",), workflows=("blast",),
                     policy_ids=policy_ids, n_seeds=n_seeds,
                     shrink=1 / 64.0)


@pytest.fixture(scope="module")
def runs():
    """One untraced + one traced sweep over the same 12-scenario grid."""
    cfg = tiny_cfg()
    tcfg = cfg.with_trace()                    # default 4·max_jobs slots
    fleet = policies.init_fleet(
        int(tiny_grid(cfg).geo_idx.max()) + 1)
    fu, mu = run_grid(tiny_grid(cfg), fleet, pred_seed=3)
    ft, mt = run_grid(tiny_grid(tcfg), fleet, pred_seed=3)
    return SimpleNamespace(cfg=cfg, tcfg=tcfg, fleet=fleet,
                           fu=fu, mu=mu, ft=ft, mt=mt,
                           grid=tiny_grid(tcfg))


# ------------------------------------------------------- ring buffer unit


def test_ring_append_order_and_decode():
    tr = T.init(4)
    mask = jnp.array([True, False, True, True])
    tr = T.append_masked(tr, mask, kind=T.EV_SUBMIT, t=jnp.float32(1.5),
                         job=jnp.arange(4, dtype=jnp.int32),
                         stage=jnp.arange(4, dtype=jnp.int32),
                         cores=jnp.full(4, 2.0), policy=jnp.int32(ASA),
                         step=jnp.int32(1))
    ev, meta = T.decode(tr)
    assert meta == {"capacity": 4, "total": 3, "kept": 3, "dropped": 0,
                    "overflowed": False}
    np.testing.assert_array_equal(ev["job"], [0, 2, 3])     # lane order
    np.testing.assert_array_equal(ev["kind"], [T.EV_SUBMIT] * 3)
    np.testing.assert_array_equal(ev["t"], [1.5] * 3)
    assert ev["job"].dtype == np.int32 and ev["t"].dtype == np.float32

    tr = T.append_if(tr, jnp.bool_(True), kind=T.EV_START,
                     t=jnp.float32(2.0), job=jnp.int32(7), stage=jnp.int32(1),
                     cores=jnp.float32(2.0), policy=jnp.int32(ASA),
                     step=jnp.int32(2))
    ev, meta = T.decode(tr)
    assert meta["total"] == 4 and not meta["overflowed"]
    np.testing.assert_array_equal(ev["job"], [0, 2, 3, 7])

    # a False flag appends nothing at all
    tr2 = T.append_if(tr, jnp.bool_(False), kind=T.EV_CANCEL,
                      t=jnp.float32(9.0), job=jnp.int32(9), stage=jnp.int32(0),
                      cores=jnp.float32(1.0), policy=jnp.int32(ASA),
                      step=jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(tr2.data), np.asarray(tr.data))
    assert int(tr2.head) == int(tr.head)


def test_ring_overflow_drops_oldest_deterministically():
    tr = T.init(4)
    for i in range(6):
        tr = T.append_if(tr, jnp.bool_(True), kind=T.EV_FINISH,
                         t=jnp.float32(10.0 + i), job=jnp.int32(i),
                         stage=jnp.int32(0), cores=jnp.float32(1.0),
                         policy=jnp.int32(ASA), step=jnp.int32(i + 1))
    assert bool(T.overflowed(tr))
    ev, meta = T.decode(tr)
    assert meta == {"capacity": 4, "total": 6, "kept": 4, "dropped": 2,
                    "overflowed": True}
    # oldest two (jobs 0, 1) fell off the front; survivors uncorrupted
    np.testing.assert_array_equal(ev["job"], [2, 3, 4, 5])
    np.testing.assert_array_equal(ev["t"], [12.0, 13.0, 14.0, 15.0])
    np.testing.assert_array_equal(ev["step"], [3, 4, 5, 6])


def test_append_segments_equals_chained_masked_appends():
    k = dict(t=jnp.float32(5.0), policy=jnp.int32(ASA_NAIVE),
             step=jnp.int32(7))
    m1 = jnp.array([False, True, True])
    m2 = jnp.array([True, False, True])
    job = jnp.arange(3, dtype=jnp.int32)
    stage = jnp.array([0, 1, 2], jnp.int32)
    cores = jnp.array([1.0, 2.0, 4.0])
    segs = [(m1, T.EV_FINISH, job, stage, cores),
            (m2, T.EV_START, job, stage, cores)]
    fused = T.append_segments(T.init(8), segs, **k)
    chained = T.append_masked(T.init(8), m1, kind=T.EV_FINISH, job=job,
                              stage=stage, cores=cores, **k)
    chained = T.append_masked(chained, m2, kind=T.EV_START, job=job,
                              stage=stage, cores=cores, **k)
    np.testing.assert_array_equal(np.asarray(fused.data),
                                  np.asarray(chained.data))
    assert int(fused.head) == int(chained.head) == 4


def test_init_rejects_bad_capacity():
    with pytest.raises(ValueError, match="capacity"):
        T.init(0)
    with pytest.raises(ValueError, match="trace_capacity"):
        XSimConfig(trace_capacity=-1)
    with pytest.raises(ValueError, match="trace_capacity"):
        tiny_cfg().with_trace(0)


# ------------------------------------------- disabled path == bit-identical


def test_tracing_disabled_path_is_bit_identical(runs):
    """trace=None vs a live ring: every non-trace leaf identical at the
    bit level — enabling observability must not move a single ULP."""
    l0 = jax.tree_util.tree_leaves_with_path(runs.fu)
    l1 = jax.tree_util.tree_leaves_with_path(runs.ft._replace(trace=None))
    assert len(l0) == len(l1)
    for (p, a), (_, b) in zip(l0, l1):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, jax.tree_util.keystr(p)
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8),
                                      err_msg=jax.tree_util.keystr(p))
    for k in runs.mu:
        np.testing.assert_array_equal(np.asarray(runs.mu[k]),
                                      np.asarray(runs.mt[k]), err_msg=k)


def test_small_ring_only_changes_the_trace(runs):
    """Shrinking the ring (forcing overflow) still perturbs nothing
    outside the trace, and keeps exactly the newest events."""
    ocfg = runs.cfg.with_trace(8)
    fo, _ = run_grid(tiny_grid(ocfg), runs.fleet, pred_seed=3)
    l0 = jax.tree_util.tree_leaves(runs.fu)
    l1 = jax.tree_util.tree_leaves(fo._replace(trace=None))
    for a, b in zip(l0, l1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    big = T.decode_batch(runs.ft.trace)
    for i, (ev, meta) in enumerate(T.decode_batch(fo.trace)):
        bev, bmeta = big[i]
        assert meta["total"] == bmeta["total"]  # head counts every event
        assert meta["kept"] == min(meta["total"], 8)
        assert meta["overflowed"] == (meta["total"] > 8)
        for f in T.FIELDS:  # survivors = newest slice of the full ring
            np.testing.assert_array_equal(
                ev[f], bev[f][meta["total"] - meta["kept"]:], err_msg=f)


# --------------------------------------------------- chrome export roundtrip


def test_chrome_trace_roundtrip(runs):
    ct = obs_export.chrome_trace(runs.ft, runs.grid.labels)
    assert obs_export.validate_chrome(ct) == []
    decoded = T.decode_batch(runs.ft.trace)
    steps = np.asarray(runs.ft.steps)
    by_pid: dict[int, list[dict]] = {}
    for e in ct["traceEvents"]:
        by_pid.setdefault(e["pid"], []).append(e)
    assert len(by_pid) == runs.grid.n
    for pid, (ev, meta) in enumerate(decoded):
        evs = by_pid[pid]
        metas = {e["name"]: e["args"] for e in evs if e["ph"] == "M"}
        # ring accounting + the steps counter round-trip exactly
        assert metas["trace_meta"] == {**meta, "steps": int(steps[pid])}
        kinds = ev["kind"]
        n_start = int((kinds == T.EV_START).sum())
        n_cancel = int((kinds == T.EV_CANCEL).sum())
        spans = [e for e in evs if e["ph"] == "X"]
        inst = [e for e in evs if e["ph"] == "i"]
        closed = [e for e in spans if not e["args"].get("open")]
        # every START becomes exactly one span unless cancelled at its
        # start instant; instants = submits/cancels/resubmits + finishes
        # of pre-sweep (warm) runs that never logged a START
        assert len(spans) == n_start - n_cancel
        n_orphan_fin = int((kinds == T.EV_FINISH).sum()) - len(closed)
        assert n_orphan_fin >= 0
        assert len(inst) == (int((kinds == T.EV_SUBMIT).sum()) + n_cancel
                             + int((kinds == T.EV_RESUBMIT).sum())
                             + n_orphan_fin)
        for e in spans:
            assert e["dur"] >= 0.0
    # the strategy labels name the process tracks
    names = [e["args"]["name"] for e in ct["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"]
    assert any("asa" in n for n in names)


def test_chrome_trace_requires_a_trace(runs):
    with pytest.raises(ValueError, match="trace"):
        obs_export.chrome_trace(runs.fu)
    with pytest.raises(ValueError, match="trace"):
        obs_export.jsonl_events(runs.fu)
    assert obs_export.trace_meta(runs.fu) is None


def test_validate_chrome_flags_malformed_events():
    errs = obs_export.validate_chrome(
        {"traceEvents": [{"ph": "Z", "pid": 0},
                         {"ph": "X", "pid": 0, "name": "a", "ts": 1.0},
                         {"ph": "i", "name": "b", "ts": 1.0}]})
    assert len(errs) == 4   # bad ph ALSO misses its ts — both named
    assert any("ph='Z'" in e or "ph=" in e for e in errs)
    assert any("dur" in e for e in errs)
    assert any("pid" in e for e in errs)


def test_export_validate_cli(tmp_path, runs):
    good = tmp_path / "trace.json"
    obs_export.write_chrome_trace(str(good), runs.ft, runs.grid.labels)
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "Z"}]}))
    assert obs_export.main(["--validate", str(good)]) == 0
    assert obs_export.main(["--validate", str(good), str(bad)]) == 1


# -------------------------------------------------------- differential test


def test_replay_chain_waits_matches_compare_metrics(runs):
    """Waits reconstructed from the trace ALONE (plus the static job
    table) must equal the engine's settled-timeline metric exactly —
    same f32 ops, same order — on all 12 mirrored QueueSim scenarios."""
    twt_engine = np.asarray(runs.mt["twt_s"], np.float32)
    n_checked = 0
    for i in range(runs.grid.n):
        s = jax.tree.map(lambda x, i=i: x[i], runs.ft)
        pwt, valid, twt = obs_metrics.replay_chain_waits(s)
        assert twt == twt_engine[i], (i, runs.grid.labels[i])
        n_checked += valid.sum()
    assert n_checked > 0  # the comparison is not vacuous


def test_replay_requires_lossless_ring(runs):
    with pytest.raises(ValueError, match="no trace"):
        obs_metrics.replay_chain_waits(
            jax.tree.map(lambda x: x[0], runs.fu))
    ocfg = runs.cfg.with_trace(8)
    fo, _ = run_grid(tiny_grid(ocfg), runs.fleet, pred_seed=3)
    s0 = jax.tree.map(lambda x: x[0], fo)
    assert bool(T.overflowed(s0.trace))
    with pytest.raises(ValueError, match="overflow"):
        obs_metrics.replay_chain_waits(s0)


# ----------------------------------------------------------- fleet metrics


def test_sweep_summary_counters(runs):
    h = obs_metrics.to_host(
        obs_metrics.sweep_summary(runs.ft, n_steps=runs.tcfg.n_steps))
    assert h["n_scenarios"] == runs.grid.n
    assert h["wf_done"] <= h["wf_total"]
    assert 0.0 <= h["drain_frac"] <= 1.0
    assert h["trace_dropped"] == 0
    # per-kind counters sum to the ring totals (nothing dropped)
    kinds = sum(h[f"ev_{n}"] for n in T.EVENT_NAMES.values())
    assert kinds == h["trace_events"]
    assert len(h["wait_hist"]) == obs_metrics.HIST_BINS
    assert sum(h["wait_hist"]) > 0
    # untraced summaries simply omit the trace-derived columns
    h0 = obs_metrics.to_host(
        obs_metrics.sweep_summary(runs.fu, n_steps=runs.cfg.n_steps))
    assert "trace_events" not in h0 and "ev_start" not in h0


def test_backfill_hits_on_crafted_scenario():
    # job1 (submitted later) starts while job0 is still queued → one hit;
    # job2 is a zero-core background row and never counts
    s = SimpleNamespace(
        submit=jnp.array([0.0, 5.0, 1.0]),
        start=jnp.array([10.0, 6.0, jnp.inf]),
        status=jnp.array([RUNNING, RUNNING, QUEUED], jnp.int32),
        cores=jnp.array([4.0, 2.0, 0.0]),
    )
    assert int(obs_metrics.backfill_hits(s)) == 1
    # no overtake once job0 starts first
    s2 = SimpleNamespace(
        submit=jnp.array([0.0, 5.0]), start=jnp.array([2.0, 6.0]),
        status=jnp.array([RUNNING, RUNNING], jnp.int32),
        cores=jnp.array([4.0, 2.0]))
    assert int(obs_metrics.backfill_hits(s2)) == 0


# --------------------------------------------------------- telemetry schema


def test_telemetry_record_roundtrip():
    rec = telemetry.record(
        "xsim_throughput",
        run={"label": "t", "freed_mode": "ref", "n_shards": 2,
             "traced": True},
        profile={"scenarios_per_sec": 100.0, "us_per_scenario": 10_000.0},
        metrics={}, trace=None)
    assert telemetry.is_telemetry(rec)
    assert telemetry.validate(rec) == []
    leg = telemetry.throughput_leg(rec)
    assert leg["freed_mode"] == "ref" and leg["n_shards"] == 2
    assert leg["traced"] is True
    assert leg["scenarios_per_sec"] == 100.0


def test_telemetry_missing_profile_is_named():
    bad = {"telemetry_version": 1, "kind": "xsim_throughput",
           "run": {}, "metrics": {}, "trace": None}
    errs = telemetry.validate(bad)
    assert any("profile" in e for e in errs)
    with pytest.raises(ValueError, match="profile"):
        telemetry.throughput_leg(bad)
    with pytest.raises(ValueError, match="profile"):
        telemetry.record("xsim_throughput", run={}, profile=None,
                         metrics={}, trace=None)
    assert any("kind" in e for e in
               telemetry.validate({"telemetry_version": 1, "kind": "wat"}))


def test_telemetry_stays_importable_without_jax():
    # bench_gate runs from a bare checkout: the schema module must not
    # drag jax in (repro is a namespace package, so importing the
    # submodule alone keeps obs.trace/metrics/export unloaded)
    import importlib.util
    import subprocess
    import sys
    spec = importlib.util.find_spec("repro.obs.telemetry")
    src_root = spec.origin.rsplit("/repro/", 1)[0]
    code = ("import sys; sys.modules['jax'] = None\n"
            f"sys.path.insert(0, {src_root!r})\n"
            "import repro.obs.telemetry as t\n"
            "assert t.validate({}) != []\n")
    subprocess.run([sys.executable, "-c", code], check=True)


# ------------------------------------------------------- bench_gate failures


def test_bench_gate_names_schema_failures(tmp_path):
    from benchmarks import bench_gate

    good = telemetry.record(
        "xsim_throughput",
        run={"label": "ok-leg", "freed_mode": "ref"},
        profile={"scenarios_per_sec": 400.0, "us_per_scenario": 2500.0},
        metrics={}, trace=None)
    (tmp_path / "xsim_throughput_ref.json").write_text(json.dumps(good))
    bad = {"telemetry_version": 1, "kind": "xsim_throughput",
           "run": {"label": "broken-leg", "freed_mode": "interpret"},
           "metrics": {}, "trace": None}      # profile section missing
    (tmp_path / "xsim_throughput_interpret.json").write_text(
        json.dumps(bad))
    (tmp_path / "xsim_throughput_garbled.json").write_text("{nope")

    legs, failures = bench_gate.collect_legs(tmp_path)
    assert list(legs) == ["ref"]              # only the valid leg merged
    assert len(failures) == 2
    named = " | ".join(failures)
    assert "profile" in named                 # says WHAT is missing
    assert "broken-leg" in named              # ...and WHICH leg
    assert "interpret" in named
    assert "xsim_throughput_garbled.json" in named


def test_bench_gate_gate_checks(tmp_path):
    from benchmarks import bench_gate

    legs = {"ref": {"scenarios_per_sec": 90.0, "us_per_scenario": 11_000.0}}
    baseline = {"legs": {"ref": {"scenarios_per_sec": 100.0,
                                 "us_per_scenario": 10_000.0}}}
    rec, fails = bench_gate.gate(legs, baseline, tolerance=0.25)
    assert rec["ok"] and not fails            # within tolerance both ways
    rec, fails = bench_gate.gate(
        {"ref": {"scenarios_per_sec": 50.0, "us_per_scenario": 20_000.0}},
        baseline, tolerance=0.25)
    assert not rec["ok"] and len(fails) == 2
    _, fails = bench_gate.gate({}, baseline, tolerance=0.25)
    assert fails and "missing" in fails[0]


# --------------------------------------------------------- CLI flag contract


def test_throughput_flags_validate_up_front(monkeypatch, capsys):
    from benchmarks import xsim_throughput

    def expect_exit(argv):
        monkeypatch.setattr("sys.argv", ["xsim_throughput"] + argv)
        with pytest.raises(SystemExit) as e:
            xsim_throughput.main()
        assert e.value.code == 2              # argparse error, pre-jit
        return capsys.readouterr().err

    err = expect_exit(["--smoke", "--trace", "t.json", "--no-trace"])
    assert "mutually exclusive" in err
    err = expect_exit(["--smoke", "--trace-capacity", "64"])
    assert "--trace" in err
    err = expect_exit(["--smoke", "--trace", "t.json",
                       "--trace-capacity", "0"])
    assert ">= 1" in err


def test_run_py_flags_validate_up_front(monkeypatch, capsys):
    # run.py parses inside __main__: re-exec its arg handling via runpy
    # (the bad flag combinations exit before any engine work starts)
    import runpy
    import sys

    def run_main(argv):
        monkeypatch.setattr(sys, "argv", ["benchmarks/run.py"] + argv)
        with pytest.raises(SystemExit) as e:
            runpy.run_module("benchmarks.run", run_name="__main__")
        return e.value.code, capsys.readouterr().err

    code, err = run_main(["--engine", "event", "--trace", "t.json"])
    assert code == 2 and "--engine xsim" in err
    code, err = run_main(["--engine", "xsim", "--trace", "t.json",
                          "--no-trace"])
    assert code == 2 and "mutually exclusive" in err
    code, err = run_main(["--engine", "event", "--json", "x.json"])
    assert code == 2 and "--engine xsim" in err
