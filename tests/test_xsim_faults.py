"""Capacity faults in the xsim scan: fail/drain/grow semantics + the
no-fault bit-identity contract.

The robustness scenario families (xsim.families) are *data*: a
``runtime.fault.FaultSchedule`` folded into the fixed-slot job table as
per-scenario arrays. These tests pin

* the three event semantics deterministically — FAIL kills the most
  recently started running jobs (LIFO) to cover the capacity deficit,
  requeues them with their original submit time (FCFS seniority kept)
  and charges the lost core-seconds as restart overhead; DRAIN removes
  free cores now and collects the remainder from completions
  (``cap_debt``), disturbing no running job; GROW adds capacity that
  admits previously-too-wide work;
* the bit-identity contract — a dynamically empty schedule (all +inf
  slots) and the statically fault-free program produce byte-identical
  states, and the ``clean`` family grid is byte-identical to a plain
  ``make_grid`` sweep;
* invariants under random schedules (hypothesis) — core conservation
  ``total − free == Σ running`` through every step, ``free ≥ 0``,
  causality ``start ≥ submit``, and full drainage of every due event.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.bins import make_bins
from repro.runtime import fault
from repro.runtime.fault import FaultSchedule
from repro.sched.workflows import MONTAGE
from repro.xsim import compare, events, policies
from repro.xsim import state as X
from repro.xsim.families import (FAMILIES, N_FAULT_SLOTS, family_grid,
                                 family_schedule)
from repro.xsim.grid import XSimConfig, make_grid, run_grid
from repro.xsim.state import add_job, empty_table, freeze

BINS = jnp.asarray(make_bins(53), jnp.float32)


def assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- deterministic semantics


def _two_running(total=8.0):
    """Two 4-core jobs running since t=0 / t=50, nothing else."""
    t = empty_table(8)
    add_job(t, 0, cores=4, duration=1000.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=1000.0)
    add_job(t, 1, cores=4, duration=1000.0, submit=0.0, status=X.RUNNING,
            start=50.0, end=1050.0)
    return t, dict(total_cores=total, free_cores=0.0)


def test_fail_kills_lifo_requeues_and_charges_restart():
    """Half the machine dies at t=100 with zero free cores: the LIFO rule
    kills job 1 (started at 50, after job 0's 0), requeues it with its
    original submit time, and charges the 4 cores × 50 s lost attempt."""
    t, kw = _two_running()
    # a later arrival competing for the post-fault machine: the requeued
    # job must keep its FCFS seniority (submit 0 < 60) and start first
    add_job(t, 2, cores=4, duration=1000.0, submit=60.0, status=X.PENDING)
    s = freeze(t, **kw, fault_sched=FaultSchedule((fault.fail(100.0, 0.5),)))
    fin = events.simulate(s, n_steps=40, faults=True)

    assert int(fin.restarts) == 1
    assert float(fin.restart_cs) == 200.0            # 4 cores × 50 s
    assert float(fin.total) == 4.0                   # 8 − 4 dead
    status = np.asarray(fin.status)
    assert list(status[:3]) == [X.DONE, X.DONE, X.DONE]
    start = np.asarray(fin.start)
    assert float(start[0]) == 0.0                    # survivor undisturbed
    assert float(fin.end[0]) == 1000.0
    # requeue causality: the killed job restarts after the fault, and its
    # kept submit time wins FCFS over the t=60 arrival
    assert float(start[1]) == 1000.0 >= 100.0
    assert float(start[2]) == 2000.0
    # conservation at the end: nothing running, all capacity free
    assert float(fin.free) == float(fin.total) == 4.0
    m = compare.metrics(fin)
    assert int(m["restarts"]) == 1
    assert float(m["restart_hours"]) == pytest.approx(200.0 / 3600.0)
    # the lost attempt is charged as overhead AND paid for in core-hours
    assert float(m["oh_hours"]) == pytest.approx(200.0 / 3600.0)


def test_drain_is_graceful_and_collects_debt_from_completions():
    """Draining 6 of 8 cores with one 4-core job running: 4 free cores
    leave now, the owed 2 are collected when the job completes — the job
    itself is never disturbed (no kills, end time unchanged)."""
    t = empty_table(4)
    add_job(t, 0, cores=4, duration=500.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=500.0)
    s = freeze(t, total_cores=8.0, free_cores=4.0,
               fault_sched=FaultSchedule((fault.drain(100.0, 0.75),)))
    fin = events.simulate(s, n_steps=20, faults=True)

    assert int(fin.restarts) == 0
    assert float(fin.end[0]) == 500.0                # undisturbed
    assert int(fin.status[0]) == X.DONE
    assert float(fin.cap_debt) == 0.0                # debt fully collected
    assert float(fin.total) == 2.0                   # 8 − 6 drained
    assert float(fin.free) == 2.0


def test_drain_clamps_to_machine_present():
    """A drain of 100% against a machine that is mostly busy removes what
    is free, owes the running remainder, and lands at total == 0."""
    t = empty_table(4)
    add_job(t, 0, cores=4, duration=500.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=500.0)
    s = freeze(t, total_cores=8.0, free_cores=4.0,
               fault_sched=FaultSchedule((fault.drain(100.0, 1.0),)))
    fin = events.simulate(s, n_steps=20, faults=True)
    assert int(fin.status[0]) == X.DONE              # work still finished
    assert float(fin.total) == 0.0
    assert float(fin.free) == 0.0
    assert float(fin.cap_debt) == 0.0


def test_grow_admits_previously_too_wide_job():
    """A 12-core job cannot start on the 8-core machine; the t=100 grow
    to 12 cores admits it at exactly the grow instant."""
    t = empty_table(4)
    add_job(t, 0, cores=12, duration=200.0, submit=0.0, status=X.PENDING)
    s = freeze(t, total_cores=8.0, free_cores=8.0,
               fault_sched=FaultSchedule((fault.grow(100.0, 0.5),)))
    fin = events.simulate(s, n_steps=20, faults=True)
    assert float(fin.start[0]) == 100.0
    assert int(fin.status[0]) == X.DONE
    assert float(fin.total) == 12.0
    assert float(fin.free) == 12.0


def test_free_cores_absorb_failure_before_kills():
    """A failure smaller than the free pool kills nothing."""
    t = empty_table(4)
    add_job(t, 0, cores=4, duration=1000.0, submit=0.0, status=X.RUNNING,
            start=0.0, end=1000.0)
    s = freeze(t, total_cores=16.0, free_cores=12.0,
               fault_sched=FaultSchedule((fault.fail(100.0, 0.5),)))
    fin = events.simulate(s, n_steps=20, faults=True)
    assert int(fin.restarts) == 0
    assert float(fin.restart_cs) == 0.0
    assert float(fin.total) == 8.0
    assert float(fin.end[0]) == 1000.0


# ------------------------------------------------- bit-identity contracts


def _workflow_scenario():
    t = empty_table(16)
    policies.add_workflow(t, 0, MONTAGE, 28, X.PER_STAGE, t0=0.0)
    return t


def test_dynamically_empty_schedule_is_bitwise_identical():
    """freeze(n_faults=2) with an EMPTY schedule (all-+inf slots) through
    the faults=True program == the statically fault-free program, bit for
    bit on every shared leaf (the (a+b)−0.0 debt-payment identity)."""
    t = _workflow_scenario()
    kw = dict(policy=X.PER_STAGE, total_cores=100.0, free_cores=100.0)
    a = events.simulate(freeze(t, **kw), n_steps=48)
    b = events.simulate(
        freeze(t, **kw, fault_sched=FaultSchedule(), n_faults=2),
        n_steps=48, faults=True)
    assert b.fault_t.shape == (2,) and bool(jnp.all(jnp.isinf(b.fault_t)))
    assert_trees_equal(a, b._replace(fault_t=a.fault_t, fault_c=a.fault_c,
                                     fault_k=a.fault_k))
    ma, mb = compare.metrics(a), compare.metrics(b)
    assert_trees_equal(ma, mb)


def test_faults_false_statically_ignores_attached_schedule():
    """``faults=False`` elides the machinery even when real events are
    attached: the arrays are dead weight, the program is the pre-fault
    one (the static-elision contract, mirroring trace=None)."""
    t = _workflow_scenario()
    kw = dict(policy=X.PER_STAGE, total_cores=100.0, free_cores=100.0)
    a = events.simulate(freeze(t, **kw), n_steps=48)
    sched = FaultSchedule((fault.fail(500.0, 0.5),))
    b = events.simulate(freeze(t, **kw, fault_sched=sched),
                        n_steps=48)                    # faults NOT enabled
    assert int(b.fault_next) == 0                      # never consumed
    assert_trees_equal(a, b._replace(fault_t=a.fault_t, fault_c=a.fault_c,
                                     fault_k=a.fault_k))


_CFG = XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                  t0=1800.0)
_GRID_KW = dict(n_seeds=1, shrink=1 / 64.0, workflows=("statistics",),
                policy_ids=(0, 1, 2))


def test_clean_family_grid_is_bitwise_identical_to_plain_grid():
    g0 = make_grid(_CFG, **_GRID_KW)
    g1 = family_grid(_CFG, "clean", **_GRID_KW)
    assert not g1.has_faults
    f0, m0 = run_grid(g0)
    f1, m1 = run_grid(g1)
    assert_trees_equal(f0, f1)
    assert_trees_equal(m0, m1)


# --------------------------------------------------- family grids end2end


@pytest.mark.parametrize("family", ["faulty", "elastic", "preempt"])
def test_family_grids_complete_and_conserve(family):
    grid = family_grid(_CFG, family, **_GRID_KW)
    assert grid.has_faults
    assert grid.fault_t.shape[1] == N_FAULT_SLOTS[family]
    final, m = run_grid(grid)
    # every workflow still finishes inside the (fault-aware) step budget
    assert np.all(np.asarray(m["wf_done"]) == np.asarray(m["wf_total"]))
    # every due event was consumed and the queue fully drained
    n_real = np.sum(np.isfinite(np.asarray(grid.fault_t)), axis=1)
    np.testing.assert_array_equal(np.asarray(final.fault_next), n_real)
    nxt = np.asarray(jax.jit(jax.vmap(
        lambda s: events.next_event_time(s, faults=True)))(final))
    assert np.all(np.isinf(nxt))
    # conservation + capacity sanity at the end of the sweep
    running = np.asarray(final.status) == X.RUNNING
    used = np.sum(np.where(running, np.asarray(final.cores), 0.0), axis=1)
    np.testing.assert_allclose(used + np.asarray(final.free),
                               np.asarray(final.total), atol=1e-3)
    assert float(jnp.min(final.min_free)) >= -1e-3
    assert np.all(np.asarray(m["restart_hours"]) >= 0.0)
    if family == "faulty":
        # fail then same-sized recovery: capacity returns to the original
        np.testing.assert_allclose(np.asarray(final.total),
                                   np.asarray(grid.centers.total_cores),
                                   atol=1e-3)


def test_family_schedules_vary_by_seed():
    a = family_schedule("faulty", {"seed": 0}, t0=0.0)
    b = family_schedule("faulty", {"seed": 1}, t0=0.0)
    assert a.events[0].t != b.events[0].t
    assert family_schedule("clean", {"seed": 0}, t0=0.0) is None
    for fam in FAMILIES:
        sched = family_schedule(fam, {"seed": 2}, t0=0.0)
        assert len(sched or ()) <= N_FAULT_SLOTS[fam]
    with pytest.raises(ValueError, match="unknown family"):
        family_schedule("bogus", {}, t0=0.0)


# --------------------------------------------------- property invariants

_MAX_JOBS = 16
_TOTAL = 64.0
_KINDS = (fault.fail, fault.drain, fault.grow)


def _faulted_scenario(seed: int, fill: float, n_events: int):
    rng = np.random.default_rng(seed)
    t = empty_table(_MAX_JOBS)
    row, used = 0, 0.0
    for _ in range(int(rng.integers(0, 6))):
        c = float(rng.integers(1, 24))
        if used + c > fill * _TOTAL:
            break
        d = float(rng.uniform(50.0, 5000.0))
        add_job(t, row, cores=c, duration=d, submit=0.0, status=X.RUNNING,
                start=0.0, end=float(rng.uniform(1.0, d)))
        used += c
        row += 1
    for _ in range(int(rng.integers(1, 6))):
        add_job(t, row, cores=float(rng.integers(1, 32)),
                duration=float(rng.uniform(50.0, 4000.0)),
                submit=float(rng.uniform(0.0, 3000.0)), status=X.PENDING)
        row += 1
    events_ = tuple(
        _KINDS[int(rng.integers(0, 3))](float(rng.uniform(1.0, 6000.0)),
                                        float(rng.uniform(0.1, 0.6)))
        for _ in range(n_events))
    return freeze(t, total_cores=_TOTAL, free_cores=_TOTAL - used,
                  fault_sched=FaultSchedule(events_))


_step_f = jax.jit(lambda s: events.sim_step(s, BINS, faults=True))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.9), st.integers(1, 4))
def test_invariants_hold_under_random_fault_schedules(seed, fill, n_events):
    s = _faulted_scenario(seed, fill, n_events)
    for _ in range(80):
        s = _step_f(s)
        status = np.asarray(s.status)
        cores = np.asarray(s.cores)
        # conservation + machine never oversubscribed nor negative
        used = float(np.sum(np.where(status == X.RUNNING, cores, 0.0)))
        assert used + float(s.free) == pytest.approx(float(s.total),
                                                     abs=1e-3)
        assert float(s.free) >= -1e-3
        assert float(s.total) >= -1e-3
        assert float(s.cap_debt) >= -1e-3
        # causality: every started job started at/after its submission
        start = np.asarray(s.start)
        started = np.isfinite(start)
        assert np.all(start[started] >= np.asarray(s.submit)[started] - 1e-3)
    # all due capacity events were consumed by the end of the run
    assert float(events.next_event_time(s, faults=True)) == np.inf
    assert int(s.fault_next) == n_events
    # restart accounting only ever accrues, consistently
    assert int(s.restarts) >= 0
    assert float(s.restart_cs) >= 0.0
    if int(s.restarts) == 0:
        assert float(s.restart_cs) == 0.0
