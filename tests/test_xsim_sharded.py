"""Sharded fleet sweeps: shard_map path ≡ single-device vmap, bit for bit.

The scenario axis is embarrassingly parallel, so ``run_grid(...,
n_shards=k)`` must reproduce the default vmap sweep exactly — final job
tables, live estimator states (including PRNG keys), RL replay buffers
and the sampled prediction sequences. These tests pin that contract on
1/2/4/8 shards, including a batch size not divisible by the shard count
(the padding mask path).

Single-device runs exercise the ``n_shards=1`` mesh + the padding
helpers; the multi-device cases skip unless enough devices are visible.
CI's ``xsim-sharded`` job fakes 8 CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
``launch.dryrun`` trick) and runs the whole file.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_scenarios_mesh
from repro.parallel import fleet as pfleet
from repro.xsim import policies
from repro.xsim.grid import XSimConfig, make_grid, run_grid, warm_fleet
from repro.xsim.state import ASA, ASA_NAIVE, BIGJOB, PER_STAGE, RL

N_DEV = len(jax.devices())

needs = pytest.mark.skipif  # readability alias for the device gates


def tiny_cfg(pred_mode: str = "greedy") -> XSimConfig:
    return XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                      t0=1800.0, pred_mode=pred_mode)


def tiny_grid(cfg, policy_ids=(BIGJOB, PER_STAGE, ASA, ASA_NAIVE),
              n_seeds=1):
    # hpc2n has 3 paper scales → B = 3 · |policies| · n_seeds
    return make_grid(cfg, center_names=("hpc2n",), workflows=("blast",),
                     policy_ids=policy_ids, n_seeds=n_seeds,
                     shrink=1 / 64.0)


def assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------- mesh + padding


def test_scenarios_mesh_validates_device_count():
    with pytest.raises(ValueError, match="device"):
        make_scenarios_mesh(N_DEV + 1)
    with pytest.raises(ValueError, match="device"):
        make_scenarios_mesh(0)
    mesh = make_scenarios_mesh(1)
    assert mesh.shape["scenarios"] == 1


def test_pad_batch_pads_with_row_zero():
    tree = {"a": jnp.arange(5.0), "b": jnp.arange(10.0).reshape(5, 2)}
    padded, mask = pfleet.pad_batch(tree, 4)
    assert padded["a"].shape == (8,) and padded["b"].shape == (8, 2)
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True] * 5 + [False] * 3)
    # pad rows replicate row 0: a valid scenario, never NaN machinery
    np.testing.assert_array_equal(np.asarray(padded["a"][5:]), [0.0] * 3)
    np.testing.assert_array_equal(np.asarray(padded["b"][5:]),
                                  np.broadcast_to([0.0, 1.0], (3, 2)))
    np.testing.assert_array_equal(
        np.asarray(pfleet.unpad(padded, 5)["a"]), np.asarray(tree["a"]))


def test_pad_batch_divisible_is_identity():
    tree = {"a": jnp.arange(6.0)}
    padded, mask = pfleet.pad_batch(tree, 3)
    assert padded["a"] is tree["a"]
    assert bool(jnp.all(mask))
    with pytest.raises(ValueError, match="n_shards"):
        pfleet.pad_batch(tree, 0)


# ------------------------------------------------- sharded ≡ vmap (1 dev)


def test_one_shard_matches_vmap_bitwise():
    cfg = tiny_cfg()
    grid = tiny_grid(cfg)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=3)
    f1, m1 = run_grid(grid, fleet, pred_seed=3, n_shards=1)
    assert_trees_equal(f0, f1)
    assert_trees_equal(m0, m1)


# --------------------------------------------- sharded ≡ vmap (multi-dev)


@pytest.mark.parametrize("k", [2, 4, 8])
def test_sharded_run_grid_bit_identical(k):
    if N_DEV < k:
        pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    # pred_mode="sample" pins the sampled prediction sequences too
    cfg = tiny_cfg(pred_mode="sample")
    grid = tiny_grid(cfg)                     # B = 12: pads on k = 8
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=3)
    fk, mk = run_grid(grid, fleet, pred_seed=3, n_shards=k)
    assert_trees_equal(f0, fk)                # incl. est PRNG keys
    assert_trees_equal(m0, mk)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_chunked_early_exit_bit_identical_across_shards(k):
    """The drain-aware chunked sweep exits per *device* (each shard's
    while_loop any-reduces over its own lanes), so devices holding
    quick-draining scenarios run fewer chunks than busy ones — and the
    gathered result must STILL be bit-identical to the single-device
    vmap, for every shard count. The grid deliberately mixes a
    single-stage probe workflow with montage so per-scenario event
    counts (and therefore per-shard chunk counts) differ wildly."""
    if N_DEV < k:
        pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.sched.workflows import Stage, Workflow

    probe = Workflow("probe1", (Stage("only", True, 600.0, 0.5),))
    cfg = tiny_cfg(pred_mode="sample")
    grid = make_grid(cfg, center_names=("hpc2n",),
                     workflows=(probe, "montage"),
                     policy_ids=(PER_STAGE, ASA, ASA_NAIVE), n_seeds=1,
                     shrink=1 / 64.0)           # B = 18: pads on k = 4, 8
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=9)
    # heterogeneous drain times: the early exit has real work to skip
    steps = np.asarray(f0.steps)
    assert int(steps.max()) > int(steps.min())
    fk, mk = run_grid(grid, fleet, pred_seed=9, n_shards=k)
    assert_trees_equal(f0, fk)                  # incl. the steps counters
    assert_trees_equal(m0, mk)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_nondivisible_batch_padding_mask():
    cfg = tiny_cfg()
    grid = tiny_grid(cfg, policy_ids=(ASA,), n_seeds=3)   # B = 9
    assert grid.n % 2 == 1                    # exercises the pad lane
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=5)
    f2, m2 = run_grid(grid, fleet, pred_seed=5, n_shards=2)
    assert pfleet.batch_size(f2) == grid.n    # pad rows sliced off
    assert_trees_equal(f0, f2)
    assert_trees_equal(m0, m2)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_warm_fleet_bit_identical():
    cfg = tiny_cfg()
    grid = tiny_grid(cfg, policy_ids=(PER_STAGE, ASA), n_seeds=2)
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    w0 = warm_fleet(fleet, grid, rounds=2)
    w2 = warm_fleet(fleet, grid, rounds=2, n_shards=2)
    assert_trees_equal(w0, w2)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_batched_metrics_matches_to_reduction_order():
    """compare.sharded_batched_metrics reduces on the shards (for fleets
    whose states stay device-resident); equal to the gathered-path
    metrics up to XLA reduction-order rounding on the summed columns."""
    from repro.xsim import compare

    cfg = tiny_cfg()
    grid = tiny_grid(cfg, policy_ids=(ASA,), n_seeds=3)   # B = 9, pads
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    final, m = run_grid(grid, fleet, pred_seed=5)
    ms = compare.sharded_batched_metrics(final, make_scenarios_mesh(2))
    assert sorted(ms) == sorted(m)
    for k in m:
        np.testing.assert_allclose(np.asarray(ms[k]), np.asarray(m[k]),
                                   rtol=1e-6, atol=0.0)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_fault_family_sweep_bit_identical_across_shards(k):
    """Capacity faults under shard_map: a faulty-family sweep (fail +
    recovery folded into the scan) must gather bit-identically to the
    single-device vmap for every shard count — the fault cursor,
    drain-debt and restart accounting are per-scenario state and must
    not observe the device topology."""
    if N_DEV < k:
        pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.xsim.families import family_grid

    cfg = tiny_cfg(pred_mode="sample")
    grid = family_grid(cfg, "faulty", center_names=("hpc2n",),
                       workflows=("blast",), n_seeds=1, shrink=1 / 64.0,
                       policy_ids=(BIGJOB, PER_STAGE, ASA, ASA_NAIVE))
    assert grid.has_faults
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=3)
    fk, mk = run_grid(grid, fleet, pred_seed=3, n_shards=k)
    assert_trees_equal(f0, fk)                # incl. fault cursors/debt
    assert_trees_equal(m0, mk)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_traced_sweep_bit_identical_across_shards(k):
    """Observability under shard_map: a traced sharded sweep must (a)
    leave every non-trace leaf bit-identical to the UNTRACED vmap run
    (the trace=None elision contract, per device) and (b) produce the
    very same event rings the traced vmap run records — tracing must
    not observe the device topology."""
    if N_DEV < k:
        pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    cfg = tiny_cfg()
    tcfg = cfg.with_trace(64)
    grid_t = tiny_grid(tcfg)                  # B = 12: pads on k = 8
    fleet = policies.init_fleet(int(grid_t.geo_idx.max()) + 1)
    f0, m0 = run_grid(tiny_grid(cfg), fleet, pred_seed=3)
    ftv, _ = run_grid(grid_t, fleet, pred_seed=3)
    ftk, mtk = run_grid(grid_t, fleet, pred_seed=3, n_shards=k)
    assert f0.trace is None and ftk.trace is not None
    assert_trees_equal(f0, ftk._replace(trace=None))
    assert_trees_equal(m0, mtk)
    assert_trees_equal(ftv.trace, ftk.trace)  # rings device-count-free


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_sweep_summary_matches_vmap():
    """obs.metrics fleet reduction inside shard_map (psum over the
    scenarios mesh, pad rows zero-weighted): integer counters exactly
    equal the vmap reduction, float columns to reduction order."""
    from repro.obs import metrics as obs_metrics

    cfg = tiny_cfg().with_trace(64)
    grid = tiny_grid(cfg, policy_ids=(ASA,), n_seeds=3)   # B = 9, pads
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    final, _ = run_grid(grid, fleet, pred_seed=5)
    s0 = obs_metrics.to_host(
        obs_metrics.sweep_summary(final, n_steps=cfg.n_steps))
    s2 = obs_metrics.to_host(obs_metrics.sharded_sweep_summary(
        final, make_scenarios_mesh(2), n_steps=cfg.n_steps))
    assert sorted(s0) == sorted(s2)
    for k in s0:
        np.testing.assert_allclose(s2[k], s0[k], rtol=1e-6, atol=0.0,
                                   err_msg=k)


@needs(N_DEV < 2, reason="needs ≥2 devices")
def test_sharded_rl_replay_buffers_bit_identical():
    from repro.rl import policy as rl_policy

    params = rl_policy.init_params(jax.random.PRNGKey(0))
    cfg = tiny_cfg()
    grid = tiny_grid(cfg, policy_ids=(RL,), n_seeds=3)    # B = 9, pads
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    f0, m0 = run_grid(grid, fleet, pred_seed=7, params=params,
                      rl_mode="sample")
    f2, m2 = run_grid(grid, fleet, pred_seed=7, params=params,
                      rl_mode="sample", n_shards=2)
    # the REINFORCE replay (obs + chosen bins) must be device-count-free
    np.testing.assert_array_equal(np.asarray(f0.rl_obs),
                                  np.asarray(f2.rl_obs))
    np.testing.assert_array_equal(np.asarray(f0.rl_act),
                                  np.asarray(f2.rl_act))
    assert_trees_equal(f0, f2)
    assert_trees_equal(m0, m2)
