"""Shared test fixtures + a minimal ``hypothesis`` fallback.

Several test modules use hypothesis property tests. On minimal
environments (the benchmark container) hypothesis is not installed, which
used to abort collection of four tier-1 modules. If the real package is
available we use it untouched; otherwise we install a tiny deterministic
stand-in that replays each ``@given`` test over a fixed set of drawn
examples (endpoints first, then seeded random draws). It covers exactly
the API surface the test-suite uses: ``given``, ``settings``,
``strategies.integers``, ``strategies.floats`` and
``strategies.booleans``.
"""

from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only when hypothesis exists
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    class _Strategy:
        def __init__(self, endpoints, sampler):
            self.endpoints = endpoints  # deterministic boundary examples
            self.sampler = sampler      # fn(rng) -> random example

    def _integers(min_value, max_value):
        return _Strategy(
            (min_value, max_value),
            lambda rng: int(rng.integers(min_value, max_value + 1)),
        )

    def _floats(min_value, max_value, **_kw):
        return _Strategy(
            (float(min_value), float(max_value)),
            lambda rng: float(rng.uniform(min_value, max_value)),
        )

    def _booleans():
        return _Strategy(
            (False, True),
            lambda rng: bool(rng.integers(0, 2)),
        )

    def _settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies, **_kw):
        def deco(fn):
            # NOTE: no functools.wraps — the wrapper must present a
            # zero-argument signature or pytest hunts for fixtures named
            # after the strategy parameters.
            def wrapper():
                # read at call time so @settings works above OR below
                # @given (real hypothesis accepts both orders)
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 10))
                rng = np.random.default_rng(0)
                cases = [tuple(s.endpoints[0] for s in strategies),
                         tuple(s.endpoints[1] for s in strategies)]
                while len(cases) < n:
                    cases.append(tuple(s.sampler(rng) for s in strategies))
                for case in cases[:n]:
                    fn(*case)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    stub = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.floats = _floats
    strategies.booleans = _booleans
    stub.given = _given
    stub.settings = _settings
    stub.strategies = strategies
    stub.__stub__ = True
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
