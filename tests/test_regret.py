"""Theorem 1 property test: empirical regret stays under the bound."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import asa
from repro.core.bins import make_bins
from repro.core.losses import zero_one
from repro.core.regret import empirical_regret, theorem1_bound


@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=3, max_value=16))
@settings(max_examples=15, deadline=None)
def test_regret_under_theorem1_bound(seed, m):
    """Random step-changing truth; default (bandit) policy; δ=0.05."""
    T = 400
    rng = np.random.default_rng(seed)
    n_seg = rng.integers(1, 6)
    truth = np.repeat(
        np.exp(rng.uniform(np.log(10), np.log(1e5), n_seg)),
        -(-T // n_seg))[:T].astype(np.float32)

    bins = jnp.asarray(make_bins(m), jnp.float32)
    s = asa.init(m, jax.random.PRNGKey(seed % 2**31))
    all_losses = np.stack(
        [np.asarray(zero_one(bins, jnp.float32(w))) for w in truth])
    chosen = []
    g = jnp.float32(1.0)
    for t in range(T):
        s, a = asa.step(s, jnp.asarray(all_losses[t]), g, policy="default")
        chosen.append(all_losses[t][int(a)])
    reg = empirical_regret(np.asarray(chosen), all_losses)
    bound = theorem1_bound(T, m, int(s.rounds), delta=0.05)
    assert reg <= bound, (reg, bound)


def test_bound_monotone_in_t_and_rounds():
    assert theorem1_bound(100, 53, 10) < theorem1_bound(1000, 53, 10)
    assert theorem1_bound(100, 53, 10) < theorem1_bound(100, 53, 50)
