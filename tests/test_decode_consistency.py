"""Decode-path == teacher-forced forward (per family)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.train.step import init_params

B, S = 2, 16


def test_dense_prefill_decode_matches_forward():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    from repro.models.transformer import (decode_step, forward,
                                          init_kv_caches, prefill)
    full = forward(params, toks, cfg)                      # (B,S,V)
    logits_pf, pf_caches = prefill(params, toks[:, :S // 2], cfg)
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, 0]), np.asarray(full[:, S // 2 - 1]),
        atol=2e-2)
    caches = init_kv_caches(cfg, B, S)
    caches = jax.tree.map(
        lambda c, p: jax.lax.dynamic_update_slice_in_dim(
            c, p.astype(c.dtype), 0, axis=2), caches, pf_caches)
    # decode the second half token by token
    for t in range(S // 2, S):
        logits, caches = decode_step(params, toks[:, t:t + 1], caches,
                                     jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-2)


def test_rwkv_decode_matches_forward():
    cfg = ARCHS["rwkv6-3b"].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    from repro.models.rwkv6 import decode_step, forward, init_decode_state
    full = forward(params, toks, cfg)
    state = init_decode_state(cfg, B)
    for t in range(S):
        logits, state = decode_step(params, toks[:, t:t + 1], state, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=3e-2)


def test_zamba_decode_matches_forward():
    cfg = ARCHS["zamba2-1.2b"].reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    from repro.models.zamba2 import decode_step, forward, init_decode_state
    full = forward(params, toks, cfg)
    state = init_decode_state(cfg, B, S)
    for t in range(S):
        logits, state = decode_step(params, toks[:, t:t + 1], state,
                                    jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=3e-2)
