"""End-to-end behaviour tests for the paper's system.

1. The full reproduction pipeline: ASA vs BigJob vs Per-Stage on the
   calibrated simulator reproduces the paper's ordering (Table 1).
2. The training framework end-to-end: loss decreases, checkpoint-restart
   resumes exactly, serve generates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_paper_ordering_on_busy_center():
    """Core claim: CH(ASA) == CH(Per-Stage) < CH(BigJob) and
    makespan(ASA) ≈ makespan(BigJob) < makespan(Per-Stage)."""
    from repro.sched.centers import UPPMAX
    from repro.sched.queue_sim import QueueSim
    from repro.sched.strategies import (ASAEstimator, run_asa, run_bigjob,
                                        run_per_stage)
    from repro.sched.workflows import MONTAGE

    est = ASAEstimator(seed=0)
    # warm-up run for the estimator (paper keeps state across runs)
    sim = QueueSim(UPPMAX, seed=21)
    sim.run_until(3600)
    run_asa(sim, MONTAGE, 640, "uppmax", est)

    results = {}
    for name, runner in [
        ("bigjob", run_bigjob), ("per_stage", run_per_stage),
        ("asa", lambda s, w, n, c: run_asa(s, w, n, c, est)),
    ]:
        sim = QueueSim(UPPMAX, seed=22)
        sim.run_until(3600)
        results[name] = runner(sim, MONTAGE, 640, "uppmax")

    r = results
    assert r["asa"].core_hours == pytest.approx(r["per_stage"].core_hours)
    assert r["asa"].core_hours < 0.6 * r["bigjob"].core_hours
    assert r["asa"].makespan_s < r["per_stage"].makespan_s
    # ASA within 2x of BigJob's makespan even on a 15h-wait queue
    assert r["asa"].makespan_s < 2.0 * r["bigjob"].makespan_s


def test_train_checkpoint_restart_exact(tmp_path):
    """Kill-and-restart equals uninterrupted run (fault tolerance)."""
    from repro.launch.train import train
    r1 = train("qwen2-0.5b", reduced=True, steps=6, batch=2, seq=32,
               ckpt_dir=None, log_every=1)
    ck = str(tmp_path / "ck")
    train("qwen2-0.5b", reduced=True, steps=4, batch=2, seq=32,
          ckpt_dir=ck, ckpt_every=4, log_every=1)
    r2 = train("qwen2-0.5b", reduced=True, steps=6, batch=2, seq=32,
               ckpt_dir=ck, ckpt_every=100, log_every=1)
    assert r2["final_loss"] == pytest.approx(r1["final_loss"], rel=1e-3)


def test_training_reduces_loss():
    from repro.launch.train import train
    r = train("gemma-2b", reduced=True, steps=25, batch=4, seq=64,
              log_every=24)
    assert r["final_loss"] < r["first_loss"]


def test_serve_generates():
    from repro.launch.serve import serve
    r = serve("qwen2-0.5b", reduced=True, batch=2, prompt_len=8, gen=4)
    assert r["tokens"].shape == (2, 4)
    assert int(jnp.max(r["tokens"])) < 256
