"""Optimizer, data pipeline, compression, sharding-rule units."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train import optimizer as OPT
from repro.train.compression import compress, compress_tree, decompress, \
    zeros_like_residuals


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = OPT.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp ||p||²
        params, opt, _ = OPT.update(params, grads, opt, lr=0.1,
                                    weight_decay=0.0)
    assert float(jnp.sum(jnp.square(params["w"]))) < 1e-2


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert float(OPT.global_norm(clipped)) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr0 = float(OPT.cosine_lr(jnp.int32(0)))
    lr_peak = float(OPT.cosine_lr(jnp.int32(100)))
    lr_end = float(OPT.cosine_lr(jnp.int32(10_000)))
    assert lr0 < lr_peak
    assert lr_end < lr_peak


def test_data_determinism_and_shapes():
    from repro.configs import ARCHS
    from repro.configs.base import ShapeSpec
    from repro.train.data import make_batch_fn
    cfg = ARCHS["qwen2-0.5b"].reduced()
    fn = make_batch_fn(cfg, ShapeSpec("t", 64, 4, "train"), seed=3)
    b1, b2 = fn(5), fn(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 64)
    b3 = fn(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(jnp.max(b1["tokens"])) < cfg.vocab_size


@given(st.integers(min_value=0, max_value=1000))
@settings(max_examples=10, deadline=None)
def test_int8_compression_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 10
    c, err = compress(x)
    xhat = decompress(c)
    # max quantization error is scale/2 per element
    assert float(jnp.max(jnp.abs(x - xhat))) <= float(c.scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(err), np.asarray(x - xhat),
                               atol=1e-6)


def test_error_feedback_preserves_sum():
    """With EF, the accumulated applied signal tracks the true signal."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64,))}
    resid = zeros_like_residuals(g)
    applied = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for i in range(50):
        gi = jax.tree.map(
            lambda x: x * (1.0 + 0.1 * jnp.sin(i * x)), g)
        ghat, resid = compress_tree(gi, resid)
        applied = applied + ghat["w"]
        total = total + gi["w"]
    # residual is bounded -> applied ≈ total
    err = float(jnp.max(jnp.abs(applied - total)))
    assert err <= float(jnp.max(jnp.abs(resid["w"]))) + 1e-4


def test_sharding_rules_divisibility():
    from repro.parallel.sharding import ShardingRules

    class FakeMesh:
        def __init__(self, shape_map):
            self.shape = shape_map
            self.axis_names = tuple(shape_map)

    rules = ShardingRules(FakeMesh({"data": 16, "model": 16}))
    # gemma: 8 heads NOT divisible by 16 -> replicated head dim
    spec = rules.spec_for("layers/attn/wq", (18, 2048, 8, 256))
    assert spec == jax.sharding.PartitionSpec(None, ("data",), None, None)
    # qwen3 experts: 128 divisible -> EP on model
    spec = rules.spec_for("layers/moe/w_gate", (94, 128, 4096, 1536))
    assert spec == jax.sharding.PartitionSpec(None, "model", ("data",), None)
    # d_ff divisible -> TP on model
    spec = rules.spec_for("layers/mlp/w_gate", (18, 2048, 16384))
    assert spec == jax.sharding.PartitionSpec(None, ("data",), "model")
    # norms replicated
    spec = rules.spec_for("layers/attn_norm/scale", (18, 2048))
    assert spec == jax.sharding.PartitionSpec(None, None)
