"""Per-arch REDUCED smoke tests: one forward + one train step on CPU,
asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.train import optimizer as OPT
from repro.train.data import make_batch_fn
from repro.train.step import init_params, make_train_step
from repro.configs.base import ShapeSpec

B, S = 2, 32


def _batch(cfg, key):
    shape = ShapeSpec("t", S, B, "train")
    return make_batch_fn(cfg, shape, seed=0)(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt = OPT.init(params)
    batch = _batch(cfg, key)
    step = jax.jit(make_train_step(cfg, remat="none"))
    params, opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), arch
    # a single step on random data should land near ln(vocab)
    import math
    assert 0.2 * math.log(cfg.vocab_size) < loss < 3 * math.log(cfg.vocab_size)
    # params stay finite
    leaves = jax.tree.leaves(params)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves), arch


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "gemma-2b",
                                  "whisper-tiny", "rwkv6-3b", "zamba2-1.2b",
                                  "pixtral-12b"])
def test_reduced_forward_shapes(arch):
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fam = cfg.family
    if fam in ("dense", "moe"):
        from repro.models.transformer import forward, padded_vocab
        logits = forward(params, toks, cfg)
        assert logits.shape == (B, S, padded_vocab(cfg))
    elif fam == "vlm":
        from repro.models.transformer import forward, padded_vocab
        pe = jax.random.normal(key, (B, 8, cfg.d_model),
                               dtype=jnp.dtype(cfg.dtype))
        logits = forward(params, toks, cfg, prefix_embeds=pe)
        assert logits.shape == (B, S + 8, padded_vocab(cfg))
    elif fam == "audio":
        from repro.models.encdec import forward
        from repro.models.transformer import padded_vocab
        frames = jax.random.normal(key, (B, cfg.encoder.n_frames, cfg.d_model))
        logits = forward(params, toks, frames, cfg)
        assert logits.shape == (B, S, padded_vocab(cfg))
    elif fam == "ssm":
        from repro.models.rwkv6 import forward
        from repro.models.transformer import padded_vocab
        logits = forward(params, toks, cfg)
        assert logits.shape == (B, S, padded_vocab(cfg))
    else:
        from repro.models.zamba2 import forward
        from repro.models.transformer import padded_vocab
        logits = forward(params, toks, cfg)
        assert logits.shape == (B, S, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_vocab_parallel_xent_matches_naive():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    from repro.train.step import model_loss
    b = _batch(cfg, key)
    l1 = float(model_loss(params, b, cfg, remat="none"))
    l2 = float(model_loss(params, b, cfg, remat="none", vocab_parallel=True))
    assert abs(l1 - l2) < 1e-3


def test_chunked_attention_matches_ref():
    import numpy as np
    from repro.models.layers import chunked_sdpa, sdpa
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(ks[i], (2, 64, 4, 16)) for i in range(3))
    for causal in (True, False):
        a = chunked_sdpa(q, k, v, causal=causal, chunk=16)
        b = sdpa(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5)
