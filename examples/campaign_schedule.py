"""ASA campaign scheduling: the paper's technique driving a multi-stage
training campaign on a batch-managed fleet (calibrated UPPMAX-like queue,
~15h waits).

Compares four submission strategies for a 5-stage campaign
(data-prep -> pretrain -> anneal -> sft -> eval, different pod geometries):
  * big-job   : one allocation at peak width for the whole campaign,
  * pilot     : one peak-width pilot allocation cycling the stages
                internally (bootstrap + per-stage dispatch latency),
  * per-stage : request each stage's allocation when the previous ends,
  * ASA       : pro-active cascade (Algorithm 1 learns the queue).

    PYTHONPATH=src python examples/campaign_schedule.py
"""

from repro.runtime.campaign import CampaignScheduler, CampaignStage
from repro.sched.centers import UPPMAX
from repro.sched.queue_sim import QueueSim
from repro.sched.strategies import (ASAEstimator, PILOT_STARTUP_S,
                                    PILOT_TASK_LATENCY_S)

STAGES = [
    CampaignStage("data-prep", 160, 1800.0, arch="-"),
    CampaignStage("pretrain", 640, 7200.0, arch="qwen3-moe-235b-a22b"),
    CampaignStage("anneal", 320, 3600.0, arch="qwen3-moe-235b-a22b"),
    CampaignStage("sft", 320, 2400.0, arch="deepseek-7b"),
    CampaignStage("eval", 160, 1200.0, arch="-"),
]


def fresh_sim(seed=42):
    sim = QueueSim(UPPMAX, seed=seed)
    sim.run_until(3600)
    return sim


def main():
    exec_s = sum(s.duration_s for s in STAGES)
    peak = max(s.slices for s in STAGES)

    # --- big job: single wait, peak width held for everything
    sim = fresh_sim()
    job = sim.submit(peak, exec_s, user="bigjob")
    sim.run_until_job_ends(job)
    big_makespan = job.end_time - job.submit_time
    big_slice_h = peak * exec_s / 3600.0

    # --- pilot job: one queue wait like big-job, plus the pilot's own
    # bootstrap + per-stage dispatch latency held at peak width
    pilot_exec = (exec_s + PILOT_STARTUP_S
                  + len(STAGES) * PILOT_TASK_LATENCY_S)
    sim = fresh_sim()
    job = sim.submit(peak, pilot_exec, user="pilot")
    sim.run_until_job_ends(job)
    pilot_makespan = job.end_time - job.submit_time
    pilot_slice_h = peak * pilot_exec / 3600.0

    # --- per-stage: sequential requests
    sim = fresh_sim()
    t0 = sim.now
    end = None
    for st in STAGES:
        j = sim.submit(st.slices, st.duration_s, user="ps")
        sim.run_until_job_ends(j)
        end = j.end_time
    ps_makespan = end - t0
    opt_slice_h = sum(s.slices * s.duration_s for s in STAGES) / 3600.0

    # --- ASA: warm the estimator on one campaign, then measure (state is
    # kept across runs, paper §4.3)
    est = ASAEstimator(seed=1)
    CampaignScheduler(fresh_sim(seed=41), est).run(STAGES)
    rep = CampaignScheduler(fresh_sim(), est).run(STAGES)

    print(f"{'strategy':10s} {'makespan_h':>10s} {'slice_h':>9s} "
          f"{'hidden_wait_h':>13s}")
    print(f"{'big-job':10s} {big_makespan/3600:10.2f} {big_slice_h:9.0f} "
          f"{'—':>13s}")
    print(f"{'pilot':10s} {pilot_makespan/3600:10.2f} {pilot_slice_h:9.0f} "
          f"{'—':>13s}")
    print(f"{'per-stage':10s} {ps_makespan/3600:10.2f} {opt_slice_h:9.0f} "
          f"{'—':>13s}")
    hidden = (sum(o.real_wait_s for o in rep.outcomes[1:])
              - sum(o.perceived_wait_s for o in rep.outcomes[1:]))
    print(f"{'ASA':10s} {rep.makespan_s/3600:10.2f} "
          f"{rep.slice_hours:9.0f} {hidden/3600:13.2f}")
    print("\nper-stage breakdown (ASA):")
    for o in rep.outcomes:
        print(f"  {o.name:10s} predicted={o.predicted_wait_s/3600:6.2f}h "
              f"real={o.real_wait_s/3600:6.2f}h "
              f"perceived={o.perceived_wait_s/3600:6.2f}h")


if __name__ == "__main__":
    main()
