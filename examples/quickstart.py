"""Quickstart: the whole stack in two minutes on CPU.

1. Train a reduced qwen2-family model for 40 steps (sharded params, AdamW,
   synthetic pipeline, async checkpoints).
2. Serve it: prefill a batch of prompts + greedy decode with a KV cache.
3. Run ASA (Algorithm 1) convergence for the three Fig.-5 policies.
4. Run a tiny vectorized fleet sweep (repro.xsim): four submission
   strategies on identical machines, one jitted program.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core.convergence import simulate
from repro.launch.serve import serve
from repro.launch.train import train


def main():
    print("=== 1. train (reduced qwen2-0.5b) ===")
    with tempfile.TemporaryDirectory() as ck:
        res = train("qwen2-0.5b", reduced=True, steps=40, batch=8, seq=64,
                    ckpt_dir=ck, ckpt_every=20, log_every=10)
    print(f"loss: {res['first_loss']:.3f} -> {res['final_loss']:.3f}\n")

    print("=== 2. serve (prefill + decode) ===")
    out = serve("qwen2-0.5b", reduced=True, batch=4, prompt_len=16, gen=8)
    print(f"generated {out['tokens'].shape} tokens "
          f"@ {out['tok_per_s']:.1f} tok/s\n")

    print("=== 3. ASA convergence (paper Fig. 5) ===")
    for policy in ("default", "tuned", "greedy"):
        r = simulate(policy, T=500, seed=3)
        print(f"{policy:8s} final-100 hit-rate: {r.hit[-100:].mean():.2f}  "
              f"regret: {r.regret[-1]:.0f}")

    print("\n=== 4. fleet sweep (xsim, policy ids 0/1/2/5) ===")
    from repro.xsim import XSimConfig, policies, run_grid
    from repro.xsim.families import family_grid
    from repro.xsim.grid import warm_fleet

    cfg = XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                     t0=1800.0)
    grid = family_grid(cfg, "clean", center_names=("hpc2n",),
                       workflows=("statistics",), n_seeds=2,
                       shrink=1 / 64.0, policy_ids=(0, 1, 2, 5))
    fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    fleet = warm_fleet(fleet, grid, rounds=2)   # §4.3 cross-run learning
    _, m = run_grid(grid, fleet)
    m = {k: np.asarray(v) for k, v in m.items()}
    by = {}
    for i, lab in enumerate(grid.labels):
        by.setdefault(lab["strategy"], []).append(i)
    print(f"{'strategy':10s} {'twt_s':>9s} {'makespan_s':>11s} "
          f"{'core_h':>7s} {'oh_h':>6s}")
    for strat, idx in by.items():
        print(f"{strat:10s} {m['twt_s'][idx].mean():9.1f} "
              f"{m['makespan_s'][idx].mean():11.1f} "
              f"{m['core_hours'][idx].mean():7.2f} "
              f"{m['oh_hours'][idx].mean():6.2f}")
    print("(swap family for 'faulty'/'elastic'/'preempt' to inject "
          "capacity faults — see src/repro/xsim/README.md)")


if __name__ == "__main__":
    main()
