"""Quickstart: the whole stack in two minutes on CPU.

1. Train a reduced qwen2-family model for 40 steps (sharded params, AdamW,
   synthetic pipeline, async checkpoints).
2. Serve it: prefill a batch of prompts + greedy decode with a KV cache.
3. Run ASA (Algorithm 1) convergence for the three Fig.-5 policies.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core.convergence import simulate
from repro.launch.serve import serve
from repro.launch.train import train


def main():
    print("=== 1. train (reduced qwen2-0.5b) ===")
    with tempfile.TemporaryDirectory() as ck:
        res = train("qwen2-0.5b", reduced=True, steps=40, batch=8, seq=64,
                    ckpt_dir=ck, ckpt_every=20, log_every=10)
    print(f"loss: {res['first_loss']:.3f} -> {res['final_loss']:.3f}\n")

    print("=== 2. serve (prefill + decode) ===")
    out = serve("qwen2-0.5b", reduced=True, batch=4, prompt_len=16, gen=8)
    print(f"generated {out['tokens'].shape} tokens "
          f"@ {out['tok_per_s']:.1f} tok/s\n")

    print("=== 3. ASA convergence (paper Fig. 5) ===")
    for policy in ("default", "tuned", "greedy"):
        r = simulate(policy, T=500, seed=3)
        print(f"{policy:8s} final-100 hit-rate: {r.hit[-100:].mean():.2f}  "
              f"regret: {r.regret[-1]:.0f}")


if __name__ == "__main__":
    main()
