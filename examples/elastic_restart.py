"""Fault tolerance + elasticity: train, kill, restart on a DIFFERENT mesh.

1. Train a reduced gemma-family model, checkpointing every 10 steps.
2. Simulate a failure (process "dies" after step 20).
3. Restart from the latest complete checkpoint — the restore path re-shards
   onto whatever mesh exists now (elastic restart after node loss).
4. Show the reshard plan a real resize would execute, and the straggler /
   heartbeat machinery that triggers it.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

from repro.launch.train import train
from repro.runtime.fault import HeartbeatTracker, StragglerMitigator


def main():
    with tempfile.TemporaryDirectory() as ck:
        print("=== phase 1: train to step 20, checkpoint every 10 ===")
        train("gemma-2b", reduced=True, steps=20, batch=4, seq=64,
              ckpt_dir=ck, ckpt_every=10, log_every=5)

        print("\n=== simulated node failure; restarting from latest ===")
        res = train("gemma-2b", reduced=True, steps=30, batch=4, seq=64,
                    ckpt_dir=ck, ckpt_every=10, log_every=5)
        print(f"resumed and finished: final loss {res['final_loss']:.3f}")

    print("\n=== reshard plan for a data-axis resize (16 -> 8) ===")
    import jax.numpy as jnp
    from repro.parallel.sharding import ShardingRules
    from repro.runtime.elastic import reshard_plan

    class FakeMesh:
        def __init__(self, shape_map):
            self.shape = shape_map
            self.axis_names = tuple(shape_map)

    params = {"layers": {"mlp": {"w_gate": jnp.zeros((18, 2048, 16384))}},
              "embed": {"table": jnp.zeros((256_256, 2048))}}
    plan = reshard_plan(params,
                        ShardingRules(FakeMesh({"data": 16, "model": 16})),
                        ShardingRules(FakeMesh({"data": 8, "model": 16})))
    for e in plan:
        print(f"  {e.path:28s} {e.old_spec:28s} -> {e.new_spec:28s} "
              f"{'MOVES' if e.moves else 'stays'} "
              f"({e.bytes_total/1e6:.0f} MB)")

    print("\n=== failure detection + straggler mitigation ===")
    hb = HeartbeatTracker(timeout_s=30.0)
    for w in range(4):
        hb.register(w, 0.0)
    for w in (0, 1, 2):
        hb.beat(w, 25.0)
    print(f"failed workers at t=40: {hb.sweep(40.0)}")

    sm = StragglerMitigator()
    for t in range(6):
        sm.start(t, 0.0)
        sm.finish(t, 9.0 + t * 0.2)
    sm.start(99, 0.0)
    print(f"stragglers at t=30: {sm.stragglers(30.0)} "
          f"(re-issued, paper §4.8 re-submission logic)")


if __name__ == "__main__":
    main()
