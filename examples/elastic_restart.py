"""Fault tolerance + elasticity: train, kill, restart on a DIFFERENT mesh.

1. Train a reduced gemma-family model, checkpointing every 10 steps.
2. Simulate a failure (process "dies" after step 20).
3. Restart from the latest complete checkpoint — the restore path re-shards
   onto whatever mesh exists now (elastic restart after node loss).
4. Show the reshard plan a real resize would execute, and the straggler /
   heartbeat machinery that triggers it.
5. Run the same story at fleet scale: a capacity-fault schedule
   (runtime.fault / runtime.elastic) injected into a vectorized xsim
   sweep — node failure mid-campaign, jobs requeued, restart overhead
   charged, capacity recovered.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

from repro.launch.train import train
from repro.runtime.fault import HeartbeatTracker, StragglerMitigator


def main():
    with tempfile.TemporaryDirectory() as ck:
        print("=== phase 1: train to step 20, checkpoint every 10 ===")
        train("gemma-2b", reduced=True, steps=20, batch=4, seq=64,
              ckpt_dir=ck, ckpt_every=10, log_every=5)

        print("\n=== simulated node failure; restarting from latest ===")
        res = train("gemma-2b", reduced=True, steps=30, batch=4, seq=64,
                    ckpt_dir=ck, ckpt_every=10, log_every=5)
        print(f"resumed and finished: final loss {res['final_loss']:.3f}")

    print("\n=== reshard plan for a data-axis resize (16 -> 8) ===")
    import jax.numpy as jnp
    from repro.parallel.sharding import ShardingRules
    from repro.runtime.elastic import reshard_plan

    class FakeMesh:
        def __init__(self, shape_map):
            self.shape = shape_map
            self.axis_names = tuple(shape_map)

    params = {"layers": {"mlp": {"w_gate": jnp.zeros((18, 2048, 16384))}},
              "embed": {"table": jnp.zeros((256_256, 2048))}}
    plan = reshard_plan(params,
                        ShardingRules(FakeMesh({"data": 16, "model": 16})),
                        ShardingRules(FakeMesh({"data": 8, "model": 16})))
    for e in plan:
        print(f"  {e.path:28s} {e.old_spec:28s} -> {e.new_spec:28s} "
              f"{'MOVES' if e.moves else 'stays'} "
              f"({e.bytes_total/1e6:.0f} MB)")

    print("\n=== failure detection + straggler mitigation ===")
    hb = HeartbeatTracker(timeout_s=30.0)
    for w in range(4):
        hb.register(w, 0.0)
    for w in (0, 1, 2):
        hb.beat(w, 25.0)
    print(f"failed workers at t=40: {hb.sweep(40.0)}")

    sm = StragglerMitigator()
    for t in range(6):
        sm.start(t, 0.0)
        sm.finish(t, 9.0 + t * 0.2)
    sm.start(99, 0.0)
    print(f"stragglers at t=30: {sm.stragglers(30.0)} "
          f"(re-issued, paper §4.8 re-submission logic)")

    print("\n=== fleet-scale what-if: fault schedules in the xsim sweep ===")
    import numpy as np
    from repro.runtime.elastic import resize_schedule
    from repro.xsim import XSimConfig, policies, run_grid
    from repro.xsim.families import family_grid
    from repro.xsim.grid import warm_fleet

    # the host-side plan the reshard above would execute, as data:
    # lose 30% of the fleet at t=2h (preempt -> kills + requeue), get
    # it back at t=4h
    plan = resize_schedule([(7200.0, -0.30), (14400.0, +0.30)],
                           preempt=True)
    t, c, k = plan.as_arrays(4, total_cores=480)
    print(f"schedule rows (t, Δcores, kind): "
          f"{[(float(a), float(b), int(d)) for a, b, d in zip(t, c, k)]}")

    # the canonical families wire exactly such schedules into every
    # scenario of a vectorized sweep
    cfg = XSimConfig(n_warm=8, n_backlog=6, n_arrivals=8, max_stages=9,
                     t0=1800.0)
    for family in ("clean", "preempt"):
        grid = family_grid(cfg, family, center_names=("hpc2n",),
                           workflows=("statistics",), n_seeds=2,
                           shrink=1 / 64.0, policy_ids=(0, 1, 2))
        fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
        fleet = warm_fleet(fleet, grid, rounds=2)
        final, m = run_grid(grid, fleet)
        m = {key: np.asarray(v) for key, v in m.items()}
        done = int(m["wf_done"].sum())
        print(f"{family:7s} workflows done {done}/{int(m['wf_total'].sum())}"
              f"  restarts/scenario {m['restarts'].mean():.2f}"
              f"  restart_h {m['restart_hours'].mean():.3f}"
              f"  mean twt_s {m['twt_s'].mean():.1f}")


if __name__ == "__main__":
    main()
