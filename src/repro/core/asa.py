"""ASA — Algorithm 1 (paper §3.2), as a pure-functional JAX module.

The algorithm maintains a distribution ``p ∈ Δ^m`` over ``m`` candidate queue
waiting times. A *round* (mini-batch, paper's outer loop) accumulates the
per-action loss vector ``ℓ_t ∈ R^m``; the inner loop runs while
``max_a ℓ_ta ≤ 1``. When a round closes, the multiplicative update

    p_{t+1,a} ∝ exp(−γ_t · ℓ_ta) · p_{t,a}

is applied and ``ℓ`` resets. ``γ_t`` is a non-increasing sequence (paper uses
``e^{−γ_t ℓ}`` with convergence proven in Appendix A for bounded round
losses; we default to γ=1.0 and expose a 1/sqrt schedule).

Everything here is jit-able, vmap-able (a fleet of per-job-geometry
estimators is one batched array program — paper §4.3 keeps one shared state
per geometry), and scan-able (the Fig.-5 convergence simulation drives
``step`` under ``lax.scan``).

State is carried in log-space for numerical robustness over millions of
multiplicative updates.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ASAState(NamedTuple):
    """Functional state of one ASA estimator."""

    log_p: jax.Array      # (m,) log of the action distribution
    round_loss: jax.Array  # (m,) ℓ_t accumulated inside the current round
    rounds: jax.Array     # ()  η(t): number of completed rounds
    t: jax.Array          # ()  total number of cases seen
    key: jax.Array        # PRNG key for action sampling

    @property
    def p(self) -> jax.Array:
        return jnp.exp(self.log_p)


def init(m: int, key: jax.Array) -> ASAState:
    """Initialise ``p_0 = 1/m`` (Algorithm 1, Require line)."""
    return ASAState(
        log_p=jnp.full((m,), -jnp.log(m), dtype=jnp.float32),
        round_loss=jnp.zeros((m,), dtype=jnp.float32),
        rounds=jnp.zeros((), dtype=jnp.int32),
        t=jnp.zeros((), dtype=jnp.int32),
        key=key,
    )


def gamma_constant(t: jax.Array, value: float = 1.0) -> jax.Array:
    return jnp.asarray(value, dtype=jnp.float32)


def gamma_sqrt(t: jax.Array, m: int, scale: float = 1.0) -> jax.Array:
    """Non-increasing γ_t = scale · sqrt(ln m / (t+1)) — Appendix-A friendly."""
    t = t.astype(jnp.float32)
    return scale * jnp.sqrt(jnp.log(float(m)) / (t + 1.0))


def sample_action(state: ASAState) -> tuple[ASAState, jax.Array]:
    """Line 4: sample an action index ``a ~ p_t``."""
    key, sub = jax.random.split(state.key)
    a = jax.random.categorical(sub, state.log_p)
    return state._replace(key=key), a


def greedy_action(state: ASAState) -> jax.Array:
    """Greedy policy (Fig. 5 red line): always the current best action."""
    return jnp.argmax(state.log_p)


def _renormalize(log_p: jax.Array) -> jax.Array:
    return log_p - jax.nn.logsumexp(log_p)


def apply_round_update(state: ASAState, gamma: jax.Array) -> ASAState:
    """Line 7: p ← e^{−γ ℓ} p / N, reset ℓ, close the round."""
    log_p = _renormalize(state.log_p - gamma * state.round_loss)
    return state._replace(
        log_p=log_p,
        round_loss=jnp.zeros_like(state.round_loss),
        rounds=state.rounds + 1,
    )


def observe(
    state: ASAState,
    action: jax.Array,
    loss: jax.Array,
    gamma: jax.Array,
) -> ASAState:
    """Lines 5–7: accumulate ℓ_ta ← ℓ_ta + ℓ(a); close the round when
    ``max_a ℓ_ta > 1`` (the inner `while` guard fails)."""
    round_loss = state.round_loss.at[action].add(loss.astype(jnp.float32))
    state = state._replace(round_loss=round_loss, t=state.t + 1)
    round_over = jnp.max(round_loss) > 1.0
    return jax.lax.cond(
        round_over,
        lambda s: apply_round_update(s, gamma),
        lambda s: s,
        state,
    )


def observe_full(
    state: ASAState,
    loss_vector: jax.Array,
    gamma: jax.Array,
    repetitions: int = 1,
) -> ASAState:
    """Tuned policy (§4.5): the *perceived* waiting time is used to
    "randomly and repeatedly adjust the probability distribution p with the
    calculated losses". We apply the full-information loss vector
    ``repetitions`` times (paper tunes repetitions = 50), which sharpens p
    around the last observation while the exp-form keeps every action's
    probability strictly positive (exploration is never extinguished)."""
    upd = gamma * loss_vector.astype(jnp.float32) * float(repetitions)
    log_p = _renormalize(state.log_p - upd)
    return state._replace(
        log_p=log_p,
        t=state.t + 1,
        rounds=state.rounds + 1,
    )


def expected_wait(state: ASAState, bins: jax.Array) -> jax.Array:
    """Posterior-mean waiting-time estimate ⟨p, θ⟩ (used for reporting)."""
    return jnp.dot(state.p, bins.astype(jnp.float32))


def map_wait(state: ASAState, bins: jax.Array) -> jax.Array:
    """Maximum-a-posteriori estimate (the bin ASA would act on greedily)."""
    return bins[jnp.argmax(state.log_p)]


def posterior_features(state: ASAState, bins: jax.Array) -> jax.Array:
    """Summary of the live posterior as policy-head observation inputs.

    Returns ``[map_wait, expected_wait, entropy]`` — the greedy estimate,
    the posterior mean, and the Shannon entropy of p (how much Algorithm 1
    still hedges). All three are jit/vmap/scan-safe reads of the state;
    ``repro.rl.features`` feeds them to the learned submission policy.
    """
    p = jnp.exp(state.log_p)
    entropy = -jnp.sum(p * state.log_p)
    b = bins.astype(jnp.float32)
    return jnp.stack([map_wait(state, b), expected_wait(state, b), entropy])


# ---------------------------------------------------------------------------
# Convenience single-step drivers (used by lax.scan simulations and the
# campaign scheduler).  The 0/1 loss of eq. (3) lives in losses.py; these
# drivers accept a precomputed per-action loss vector so any loss plugs in.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("policy", "repetitions"))
def step(
    state: ASAState,
    loss_vector: jax.Array,
    gamma: jax.Array,
    *,
    policy: str = "default",
    repetitions: int = 50,
) -> tuple[ASAState, jax.Array]:
    """One ASA decision: pick an action, incur its loss, learn.

    Returns (new_state, chosen_action). ``loss_vector`` is the (m,) loss each
    action *would* incur for this case — the bandit policies only look at the
    chosen entry, the tuned policy uses the full vector (it has observed the
    true wait after the fact, which is exactly the information a submitted
    job's completion reveals).
    """
    if policy == "greedy":
        a = greedy_action(state)
        state = observe(state, a, loss_vector[a], gamma)
    elif policy == "default":
        state, a = sample_action(state)
        state = observe(state, a, loss_vector[a], gamma)
    elif policy == "tuned":
        state, a = sample_action(state)
        state = observe(state, a, loss_vector[a], gamma)
        state = observe_full(state, loss_vector, gamma / 50.0, repetitions)
    else:  # pragma: no cover - guarded by static arg
        raise ValueError(f"unknown policy {policy!r}")
    return state, a


# ---------------------------------------------------------------------------
# Scan-safe conditional drivers.  The xsim batched engine carries an ASAState
# through a ``lax.scan`` and fires estimator events (Algorithm-1 line-4 draws
# at stage submissions, tuned §4.5 updates at stage starts) behind data-
# dependent predicates.  ``lax.cond`` keeps the PRNG untouched on the no-op
# path, so the key-consumption *order* matches the event-driven
# ``strategies.ASAEstimator`` call-for-call — the property differential
# cross-validation relies on.
# ---------------------------------------------------------------------------


def sample_wait_if(state: ASAState, bins: jax.Array, do: jax.Array,
                   greedy: jax.Array | bool = False
                   ) -> tuple[ASAState, jax.Array]:
    """Draw a waiting-time estimate, only when ``do`` is True.

    ``greedy=False``: Algorithm-1 line-4 categorical draw — the key is
    split (and the draw made) only on the True branch, mirroring
    ``ASAEstimator.predict`` for the tuned policy call-for-call.
    ``greedy=True``: the current MAP wait, no key consumed — consistent
    across a scenario's stages, which is what keeps the §3.2 cascade
    stable when p is still multi-modal (over-prediction cancels out of
    E_y − a_{y+1} when the estimates agree). A *python* bool stakes the
    choice out at trace time (the fleet sweep's hot path never traces the
    RNG); a traced bool selects per scenario.
    """
    b = bins.astype(jnp.float32)

    def pick_map(s: ASAState) -> tuple[ASAState, jax.Array]:
        return s, b[greedy_action(s)]

    def pick_sample(s: ASAState) -> tuple[ASAState, jax.Array]:
        s, a = sample_action(s)
        return s, b[a]

    if isinstance(greedy, bool):
        yes = pick_map if greedy else pick_sample
    else:
        def yes(s: ASAState) -> tuple[ASAState, jax.Array]:
            return jax.lax.cond(greedy, pick_map, pick_sample, s)

    return jax.lax.cond(do, yes, lambda s: (s, jnp.float32(0.0)), state)


def learn_wait_if(state: ASAState, bins: jax.Array, true_wait: jax.Array,
                  do: jax.Array, gamma: float = 1.0) -> ASAState:
    """One within-run learning event, only when ``do`` is True.

    Replicates ``strategies.ASAEstimator.learn`` (= ``step`` with the
    tuned §4.5 policy at its default 50 repetitions, whose γ/50 divisor
    the repetition count cancels) without the jit wrapper: sample,
    observe the chosen entry of the eq.-(3) zero/one loss at the observed
    wait, then the full-information sharpening pass.
    """
    from repro.core.losses import zero_one

    lv = zero_one(bins.astype(jnp.float32),
                  jnp.maximum(true_wait.astype(jnp.float32), 1.0))
    g = jnp.float32(gamma)

    def yes(s: ASAState) -> ASAState:
        s, a = sample_action(s)
        s = observe(s, a, lv[a], g)
        return observe_full(s, lv, g / 50.0, 50)

    return jax.lax.cond(do, yes, lambda s: s, state)


def init_batch(m: int, n: int, key: jax.Array) -> ASAState:
    """A fleet of ``n`` independent estimators (one per job geometry)."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init(m, k))(keys)


batched_step = jax.vmap(
    lambda s, lv, g: step(s, lv, g), in_axes=(0, 0, None), out_axes=(0, 0)
)
