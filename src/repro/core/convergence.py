"""Fig.-5 convergence simulation (paper §4.4).

1000 iterations; the *true* waiting time step-changes at iterations
0/200/400/600/800; three sampling policies are compared:
greedy (red), default (black), tuned repetition=50 (pink).

The whole simulation is one ``lax.scan`` — per-iteration work is a single
ASA step, so the 3-policy × 1000-step sim runs in milliseconds.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import asa
from repro.core.bins import make_bins
from repro.core.losses import zero_one


class ConvergenceResult(NamedTuple):
    true_wait: np.ndarray      # (T,)
    estimate: np.ndarray       # (T,) MAP wait estimate per iteration
    expected: np.ndarray       # (T,) posterior-mean estimate
    hit: np.ndarray            # (T,) 1 where the chosen action was optimal
    regret: np.ndarray         # (T,) cumulative chosen-loss − best-fixed loss
    rounds: np.ndarray         # (T,) η(t) trajectory


def default_truth_schedule(key: jax.Array, T: int = 1000,
                           n_changes: int = 5) -> jnp.ndarray:
    """True wait step-changes at iterations 0, T/5, 2T/5, ... (paper: 0, 200,
    400, 600, 800). Values drawn log-uniformly over the bin range."""
    pts = jax.random.uniform(key, (n_changes,), minval=jnp.log(10.0),
                             maxval=jnp.log(100_000.0))
    vals = jnp.exp(pts)
    seg = T // n_changes
    return jnp.repeat(vals, seg, total_repeat_length=T)


@partial(jax.jit, static_argnames=("policy", "m", "T", "repetitions"))
def _simulate(key: jax.Array, truth: jax.Array, *, policy: str, m: int,
              T: int, gamma: float, repetitions: int):
    bins = jnp.asarray(make_bins(m), dtype=jnp.float32)
    state = asa.init(m, key)

    def body(state, w):
        lv = zero_one(bins, w)
        g = jnp.asarray(gamma, jnp.float32)
        state, a = asa.step(state, lv, g, policy=policy,
                            repetitions=repetitions)
        est = asa.map_wait(state, bins)
        exp_est = asa.expected_wait(state, bins)
        chosen_loss = lv[a]
        return state, (est, exp_est, 1.0 - chosen_loss, chosen_loss,
                       state.rounds)

    state, (est, exp_est, hit, chosen_loss, rounds) = jax.lax.scan(
        body, state, truth, length=T)
    # best fixed action in hindsight (per Theorem 1's comparator θ̄)
    all_losses = jax.vmap(lambda w: zero_one(bins, w))(truth)  # (T, m)
    best_fixed = jnp.min(jnp.cumsum(all_losses, axis=0), axis=1)
    regret = jnp.cumsum(chosen_loss) - best_fixed
    return est, exp_est, hit, regret, rounds


def simulate(
    policy: str = "default",
    *,
    T: int = 1000,
    m: int = 53,
    gamma: float = 1.0,
    repetitions: int = 50,
    seed: int = 0,
    truth: np.ndarray | None = None,
) -> ConvergenceResult:
    key = jax.random.PRNGKey(seed)
    tkey, skey = jax.random.split(key)
    if truth is None:
        truth_arr = default_truth_schedule(tkey, T)
    else:
        truth_arr = jnp.asarray(truth, dtype=jnp.float32)
    est, exp_est, hit, regret, rounds = _simulate(
        skey, truth_arr, policy=policy, m=m, T=T, gamma=gamma,
        repetitions=repetitions)
    return ConvergenceResult(
        true_wait=np.asarray(truth_arr),
        estimate=np.asarray(est),
        expected=np.asarray(exp_est),
        hit=np.asarray(hit),
        regret=np.asarray(regret),
        rounds=np.asarray(rounds),
    )
