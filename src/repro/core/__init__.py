"""repro.core — the paper's contribution: ASA, Algorithm 1 + analysis."""

from repro.core import asa, bins, convergence, losses, regret  # noqa: F401
from repro.core.asa import ASAState, init, observe, observe_full, step  # noqa: F401
from repro.core.bins import M_DEFAULT, make_bins, nearest_bin  # noqa: F401
