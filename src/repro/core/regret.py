"""Theorem 1 (Appendix A): the regret bound ASA provably satisfies.

    Σ_{s≤t} ℓ_s(θ^{s−1}) − Σ_{s≤t} ℓ_s(θ̄)
        ≤ 4 η(t) + ln(m) + sqrt(2 t ln(m/δ))      w.p. ≥ 1 − δ

where η(t) is the number of adaptive mini-batches (rounds) the algorithm
created by time t. Property tests assert empirical regret stays under this
bound across random loss sequences.
"""

from __future__ import annotations

import numpy as np


def theorem1_bound(t: int, m: int, eta_t: int, delta: float = 0.05) -> float:
    if not (0.0 < delta < 1.0):
        raise ValueError("delta must be in (0, 1)")
    return 4.0 * eta_t + np.log(m) + np.sqrt(2.0 * t * np.log(m / delta))


def empirical_regret(chosen_losses: np.ndarray,
                     all_losses: np.ndarray) -> float:
    """Regret vs the best *fixed* action in hindsight.

    chosen_losses: (T,) losses the algorithm actually incurred.
    all_losses:    (T, m) loss every action would have incurred per step.
    """
    best_fixed = float(np.min(np.sum(all_losses, axis=0)))
    return float(np.sum(chosen_losses)) - best_fixed
