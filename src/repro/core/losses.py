"""Loss functions scored against the true queue waiting time.

Eq. (3) of the paper: ℓ_y(a) = 0 if the sampled action is the *best possible*
alternative (closest to the true wait) among the m candidates, 1 otherwise.

Beyond-paper shaped losses are provided for the sensitivity study: they award
partial credit by distance in log-wait space, and an asymmetric variant that
penalizes under-estimation (job not ready ⇒ makespan grows) harder than
over-estimation (resources idle ⇒ bounded core-hour OH, paper §2.3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def zero_one(bins: jax.Array, true_wait: jax.Array) -> jax.Array:
    """Eq. (3): (m,) vector with 0 at the closest-to-truth bin, 1 elsewhere."""
    d = jnp.abs(jnp.log(bins) - jnp.log(jnp.maximum(true_wait, 1e-9)))
    best = jnp.argmin(d)
    return jnp.where(jnp.arange(bins.shape[0]) == best, 0.0, 1.0)


def log_distance(bins: jax.Array, true_wait: jax.Array) -> jax.Array:
    """Shaped loss in [0,1]: normalized |log a − log w|. Beyond-paper."""
    d = jnp.abs(jnp.log(bins) - jnp.log(jnp.maximum(true_wait, 1e-9)))
    return jnp.clip(d / jnp.log(bins[-1] / bins[0]), 0.0, 1.0)


def asymmetric(
    bins: jax.Array,
    true_wait: jax.Array,
    under_weight: float = 1.0,
    over_weight: float = 0.5,
) -> jax.Array:
    """Beyond-paper: under-estimation (a < w ⇒ the next stage is NOT ready
    when the current one drains ⇒ full makespan hit) weighted above
    over-estimation (a > w ⇒ allocation idles, bounded OH cost)."""
    logb = jnp.log(bins)
    logw = jnp.log(jnp.maximum(true_wait, 1e-9))
    d = logb - logw
    scale = jnp.log(bins[-1] / bins[0])
    shaped = jnp.where(
        d < 0, under_weight * (-d) / scale, over_weight * d / scale
    )
    return jnp.clip(shaped, 0.0, 1.0)


LOSSES = {
    "zero_one": zero_one,
    "log_distance": log_distance,
    "asymmetric": asymmetric,
}
