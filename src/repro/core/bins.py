"""Wait-time discretization grid (paper §4.3).

The paper sets ``m = 53`` alternatives covering queue waiting times up to
~28 hours (100k seconds): "multiples of 10's, 100's, 1k's, 10k's, and 100k
time intervals (in seconds), with higher number of alternatives assigned to
values 10's and 100's due to the higher queue waiting times variability
usually faced by smaller jobs".

We realize that as the grid

    10..90   step 10   (9 bins)       "10's"   — dense low range
    100..975 step 25   (36 bins)      "100's"  — densest range (small jobs)
    1k..9k   step 2k   (5 bins)       "1k's"
    10k..50k step 20k  (3 bins)       wait, see below

plus ``{10_000, 50_000, 100_000}`` for the heavy tail — 53 bins total.
Exact placement inside each decade is not specified by the paper; what the
paper pins down is (a) m == 53, (b) coverage to 1e5 s, (c) density skewed to
the 10s/100s decades. The grid below satisfies all three and is what every
experiment in this repo uses.
"""

from __future__ import annotations

import numpy as np

MAX_WAIT_SECONDS = 100_000.0  # ~28 h, max observed wait in both centers
M_DEFAULT = 53


def make_bins(m: int = M_DEFAULT) -> np.ndarray:
    """Return the ``m``-vector of candidate waiting times, in seconds.

    For the paper-default ``m == 53`` the grid is hand-shaped per §4.3.
    Other values of m use a log-spaced grid over [10, 1e5] (used by
    sensitivity tests and the hypothesis sweeps).
    """
    if m == 53:
        tens = np.arange(10.0, 100.0, 10.0)          # 9 bins:  10..90
        hundreds = np.arange(100.0, 1000.0, 25.0)    # 36 bins: 100..975
        thousands = np.array([1e3, 2e3, 4e3, 7e3])   # 4 bins
        tenk = np.array([1e4, 2e4, 5e4])             # 3 bins
        tail = np.array([1e5])                       # 1 bin
        grid = np.concatenate([tens, hundreds, thousands, tenk, tail])
        assert grid.shape == (53,), grid.shape
        return grid
    if m < 2:
        raise ValueError("need at least 2 alternatives")
    return np.logspace(np.log10(10.0), np.log10(MAX_WAIT_SECONDS), m)


def nearest_bin(bins: np.ndarray, wait_seconds) -> np.ndarray:
    """Index of the alternative closest (in log space) to a true wait."""
    w = np.maximum(np.asarray(wait_seconds, dtype=np.float64), 1e-9)
    d = np.abs(np.log(bins)[None, ...] - np.log(w)[..., None])
    return np.argmin(d, axis=-1)
