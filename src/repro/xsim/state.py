"""Slotted scenario state for the vectorized batch-queue simulator.

One scenario is a fixed-size *job table*: every job the scenario will ever
see — warm-start running jobs, queued backlog, future background arrivals
and the workflow's stage jobs — occupies one row from t=0. Rows move
through a status ladder (INVALID → PENDING → QUEUED → RUNNING → DONE) via
masked array writes, so the whole simulation is a pure JAX program:
``lax.scan`` advances event time, ``jax.vmap`` runs thousands of
independent scenarios as one batched program (see events.py / grid.py).

This trades the event-driven simulator's unbounded heap for a static
``(max_jobs,)`` shape — the price of jit: scenarios must declare an upper
bound on how many jobs they contain. See README.md for the full list of
approximations vs. ``repro.sched.queue_sim.QueueSim``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# --- job status ladder -----------------------------------------------------
INVALID = 0   # empty slot (padding)
PENDING = 1   # exists but not yet submitted (submit time possibly unknown)
QUEUED = 2    # submitted, waiting in the FCFS queue
RUNNING = 3
DONE = 4

# --- scenario policy ids (mirrors sched.strategies) ------------------------
BIGJOB = 0
PER_STAGE = 1
ASA = 2

POLICY_NAMES = ("bigjob", "per_stage", "asa")

INF = jnp.inf


class ScenarioState(NamedTuple):
    """One scenario's full simulation state (a pytree of arrays).

    Job-table fields are ``(max_jobs,)``; the rest are scalars. ``vmap``
    over the leading axis gives a fleet of scenarios.
    """

    # job table ------------------------------------------------------------
    submit: jax.Array       # f32 (max_jobs,) submission time; +inf = unreleased
    cores: jax.Array        # f32 (max_jobs,)
    duration: jax.Array     # f32 (max_jobs,)
    start: jax.Array        # f32 (max_jobs,) +inf until started
    end: jax.Array          # f32 (max_jobs,) +inf until start (then start+dur)
    status: jax.Array       # i32 (max_jobs,)
    start_dep: jax.Array    # i32 (max_jobs,) row idx of afterok dep, -1 none
    wf_next: jax.Array      # i32 (max_jobs,) successor stage row, -1 none
    is_wf: jax.Array        # bool (max_jobs,) workflow (not background) job
    pred_wait: jax.Array    # f32 (max_jobs,) ASA's sampled wait estimate a_y
    expected_end: jax.Array  # f32 (max_jobs,) ASA chain E[end_y]; -inf unset
    # scalars ---------------------------------------------------------------
    t: jax.Array            # f32 () current simulation time
    free: jax.Array         # f32 () free cores
    total: jax.Array        # f32 () machine size
    policy: jax.Array       # i32 () BIGJOB / PER_STAGE / ASA
    t0: jax.Array           # f32 () workflow submission epoch
    busy_cs: jax.Array      # f32 () ∫ used_cores dt  (utilization integral)
    min_free: jax.Array     # f32 () min free cores ever seen (invariant probe)


def empty_table(max_jobs: int) -> dict[str, np.ndarray]:
    """A host-side (numpy) job table of INVALID rows, ready to fill."""
    return {
        "submit": np.full(max_jobs, np.inf, np.float32),
        "cores": np.zeros(max_jobs, np.float32),
        "duration": np.zeros(max_jobs, np.float32),
        "start": np.full(max_jobs, np.inf, np.float32),
        "end": np.full(max_jobs, np.inf, np.float32),
        "status": np.full(max_jobs, INVALID, np.int32),
        "start_dep": np.full(max_jobs, -1, np.int32),
        "wf_next": np.full(max_jobs, -1, np.int32),
        "is_wf": np.zeros(max_jobs, bool),
        "pred_wait": np.zeros(max_jobs, np.float32),
        "expected_end": np.full(max_jobs, -np.inf, np.float32),
    }


def freeze(table: dict[str, np.ndarray], *, total_cores: float,
           free_cores: float, now: float = 0.0, policy: int = BIGJOB,
           t0: float = 0.0) -> ScenarioState:
    """Build a device ScenarioState from a host-side table + scalars."""
    return ScenarioState(
        **{k: jnp.asarray(v) for k, v in table.items()},
        t=jnp.float32(now),
        free=jnp.float32(free_cores),
        total=jnp.float32(total_cores),
        policy=jnp.int32(policy),
        t0=jnp.float32(t0),
        busy_cs=jnp.float32(0.0),
        min_free=jnp.float32(free_cores),
    )


def add_job(table: dict[str, np.ndarray], row: int, *, cores: float,
            duration: float, submit: float = np.inf, status: int = PENDING,
            start: float = np.inf, end: float = np.inf, start_dep: int = -1,
            wf_next: int = -1, is_wf: bool = False,
            pred_wait: float = 0.0) -> None:
    """Fill one host-side table row (scenario construction helper)."""
    table["submit"][row] = submit
    table["cores"][row] = cores
    table["duration"][row] = duration
    table["start"][row] = start
    table["end"][row] = end
    table["status"][row] = status
    table["start_dep"][row] = start_dep
    table["wf_next"][row] = wf_next
    table["is_wf"][row] = is_wf
    table["pred_wait"][row] = pred_wait
