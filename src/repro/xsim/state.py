"""Slotted scenario state for the vectorized batch-queue simulator.

One scenario is a fixed-size *job table*: every job the scenario will ever
see — warm-start running jobs, queued backlog, future background arrivals
and the workflow's stage jobs — occupies one row from t=0. Rows move
through a status ladder (INVALID → PENDING → QUEUED → RUNNING → DONE) via
masked array writes, so the whole simulation is a pure JAX program:
``lax.scan`` advances event time, ``jax.vmap`` runs thousands of
independent scenarios as one batched program (see events.py / grid.py).

ASA-Naive (§4.5) adds one backwards edge to the ladder: an allocation
granted long before its predecessor finishes is CANCELLED at its start
instant and re-enters the queue (CANCELLED → QUEUED) once the predecessor
completes — the only non-monotone transition, and it is always explicit.

Each scenario also carries its own live ``core.asa.ASAState`` (the
per-geometry Algorithm-1 estimator), so cascade wait estimates are
sampled — and the estimator updated — *inside* the ``lax.scan``, matching
the event-driven runner's within-run learning.

This trades the event-driven simulator's unbounded heap for a static
``(max_jobs,)`` shape — the price of jit: scenarios must declare an upper
bound on how many jobs they contain. See README.md for the full list of
approximations vs. ``repro.sched.queue_sim.QueueSim``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import asa
from repro.core.bins import M_DEFAULT
from repro.obs import trace as obs_trace

# --- job status ladder -----------------------------------------------------
INVALID = 0   # empty slot (padding)
PENDING = 1   # exists but not yet submitted (submit time possibly unknown)
QUEUED = 2    # submitted, waiting in the FCFS queue
RUNNING = 3
DONE = 4
CANCELLED = 5  # ASA-Naive early allocation, cancelled at start (§4.5)

# --- scenario policy ids (mirrors sched.strategies) ------------------------
BIGJOB = 0
PER_STAGE = 1
ASA = 2
ASA_NAIVE = 3
RL = 4         # learned submission-policy head (repro.rl), naive-world rows
PILOT = 5      # pilot job: one peak-cores allocation, stages cycled inside

POLICY_NAMES = ("bigjob", "per_stage", "asa", "asa_naive", "rl", "pilot")

INF = jnp.inf

M_BINS = M_DEFAULT  # paper §4.3 wait-time alternatives (m = 53)

# Observation width of the learned policy head. Lives here (not in
# repro.rl.features, which builds exactly this many entries) because the
# ScenarioState trajectory buffers need the size and rl.features imports
# this module — the reverse import would be a cycle.
RL_FEATURES = 12


class ScenarioState(NamedTuple):
    """One scenario's full simulation state (a pytree of arrays).

    Job-table fields are ``(max_jobs,)``, ``wf_rows`` is ``(max_stages,)``,
    ``est`` is the scenario's live ASA estimator, the rest are scalars.
    ``vmap`` over the leading axis gives a fleet of scenarios.
    """

    # job table ------------------------------------------------------------
    submit: jax.Array       # f32 (max_jobs,) submission time; +inf = unreleased
    cores: jax.Array        # f32 (max_jobs,)
    duration: jax.Array     # f32 (max_jobs,)
    start: jax.Array        # f32 (max_jobs,) +inf until started
    end: jax.Array          # f32 (max_jobs,) +inf until start (then start+dur)
    status: jax.Array       # i32 (max_jobs,)
    start_dep: jax.Array    # i32 (max_jobs,) row idx of afterok dep, -1 none
    wf_next: jax.Array      # i32 (max_jobs,) successor stage row, -1 none
    is_wf: jax.Array        # bool (max_jobs,) workflow (not background) job
    pred_wait: jax.Array    # f32 (max_jobs,) ASA's live-sampled estimate a_y
    expected_end: jax.Array  # f32 (max_jobs,) ASA chain E[end_y]; -inf unset
    # workflow chain (stage-indexed, (max_stages,)) ------------------------
    wf_rows: jax.Array      # i32 stage y -> row idx, -1 none
    hold: jax.Array         # f32 naive idle-hold before stage y's compute
    canc_start: jax.Array   # f32 stage y's cancelled attempt's start; +inf
    start_pending: jax.Array  # bool stage start-hook not yet processed
    chain_pending: jax.Array  # bool stage chain-hook not yet processed
    # learned-policy trajectory (REINFORCE replay buffer, (max_stages, ·)) -
    rl_obs: jax.Array       # f32 (max_stages, RL_FEATURES) obs at each draw
    rl_act: jax.Array       # i32 (max_stages,) chosen wait bin; -1 = no draw
    # live estimator -------------------------------------------------------
    est: asa.ASAState       # this scenario's Algorithm-1 state (learns in-scan)
    # scalars ---------------------------------------------------------------
    t: jax.Array            # f32 () current simulation time
    free: jax.Array         # f32 () free cores
    total: jax.Array        # f32 () machine size
    policy: jax.Array       # i32 () BIGJOB / PER_STAGE / ASA / ASA_NAIVE
    t0: jax.Array           # f32 () workflow submission epoch
    busy_cs: jax.Array      # f32 () ∫ used_cores dt  (utilization integral)
    min_free: jax.Array     # f32 () min free cores ever seen (invariant probe)
    oh_cs: jax.Array        # f32 () naive over-allocation core-seconds (OH)
    misses: jax.Array       # i32 () naive early-start (misprediction) count
    repass: jax.Array       # bool () force an extra same-time step next
    pred_greedy: jax.Array  # bool () MAP (consistent) vs line-4 sampled a_y
    steps: jax.Array        # i32 () event steps executed (drained no-ops
    #   don't count) — the budget-vs-event profile signal
    # capacity faults (runtime.fault.FaultSchedule, per-scenario data) ------
    fault_t: jax.Array      # f32 (n_faults,) event times, sorted; +inf pad
    fault_c: jax.Array      # f32 (n_faults,) capacity delta in cores (>= 0)
    fault_k: jax.Array      # i32 (n_faults,) FAULT_FAIL / DRAIN / GROW
    fault_next: jax.Array   # i32 () next unprocessed fault-event index
    cap_debt: jax.Array     # f32 () draining cores still owed (collected
    #   from freed cores as running work completes)
    restarts: jax.Array     # i32 () jobs killed by failures and requeued
    restart_cs: jax.Array   # f32 () lost core-seconds of killed attempts
    pilot_waste_cs: jax.Array  # f32 () pilot over-allocation core-seconds
    #   (packing waste + startup + dispatch), charged once the pilot runs
    # observability ---------------------------------------------------------
    trace: "obs_trace.TraceBuffer | None" = None  # event ring buffer
    #   (repro.obs.trace); None statically elides every trace append —
    #   the untraced program, bit for bit (pinned by tests/test_obs.py)


def empty_table(max_jobs: int) -> dict[str, np.ndarray]:
    """A host-side (numpy) job table of INVALID rows, ready to fill."""
    return {
        "submit": np.full(max_jobs, np.inf, np.float32),
        "cores": np.zeros(max_jobs, np.float32),
        "duration": np.zeros(max_jobs, np.float32),
        "start": np.full(max_jobs, np.inf, np.float32),
        "end": np.full(max_jobs, np.inf, np.float32),
        "status": np.full(max_jobs, INVALID, np.int32),
        "start_dep": np.full(max_jobs, -1, np.int32),
        "wf_next": np.full(max_jobs, -1, np.int32),
        "is_wf": np.zeros(max_jobs, bool),
        "pred_wait": np.zeros(max_jobs, np.float32),
        "expected_end": np.full(max_jobs, -np.inf, np.float32),
    }


def freeze(table: dict[str, np.ndarray], *, total_cores: float,
           free_cores: float, now: float = 0.0, policy: int = BIGJOB,
           t0: float = 0.0, max_stages: int = 9,
           est: asa.ASAState | None = None,
           est_seed: int = 0, pred_mode: str = "sample",
           trace_capacity: int = 0, fault_sched=None,
           n_faults: int | None = None,
           pilot_waste_cs: float = 0.0) -> ScenarioState:
    """Build a device ScenarioState from a host-side table + scalars.

    ``wf_rows`` (the stage chain) is derived from ``is_wf`` row order.
    ``est`` seeds the scenario's live estimator; the default is a fresh
    uniform Algorithm-1 state keyed by ``est_seed`` — pass the state of a
    warmed/persisted estimator to mirror a cross-run ASA (§4.3).
    ``pred_mode="sample"`` (default) draws cascade estimates a_y by the
    Algorithm-1 line-4 rule, matching the event-driven tuned runner
    call-for-call (the cross-validation setting); ``"greedy"`` uses the
    live MAP, the fleet-sweep default (see grid.XSimConfig).
    ``trace_capacity > 0`` attaches a ``repro.obs.trace`` event ring of
    that many slots; 0 (default) leaves ``trace=None`` — the untraced
    program, statically.
    ``fault_sched`` (a ``runtime.fault.FaultSchedule``) attaches a
    capacity-fault schedule; ``n_faults`` pads the event arrays to a
    fixed slot count (default: exactly the schedule's length). Run the
    result with ``events.simulate(..., faults=True)`` — the fault
    machinery is statically elided otherwise.
    ``pilot_waste_cs`` is the PILOT policy's over-allocation
    core-seconds (``sched.strategies.pilot_waste_cs``), charged as OH by
    ``compare.metrics`` once the pilot row runs.
    """
    from repro.runtime.fault import FaultSchedule

    if pred_mode not in ("sample", "greedy"):
        raise ValueError(f"unknown pred_mode {pred_mode!r}")
    if trace_capacity < 0:
        raise ValueError(
            f"trace_capacity must be >= 0, got {trace_capacity}")
    if fault_sched is None:
        fault_sched = FaultSchedule()
    if n_faults is None:
        n_faults = len(fault_sched)
    ft, fc, fk = fault_sched.as_arrays(n_faults, total_cores)
    max_jobs = table["status"].shape[0]
    wf_idx = np.nonzero(table["is_wf"])[0]
    if len(wf_idx) > max_stages:
        raise ValueError(f"{len(wf_idx)} workflow rows > max_stages")
    wf_rows = np.full(max_stages, -1, np.int32)
    wf_rows[:len(wf_idx)] = wf_idx
    if est is None:
        est = asa.init(M_BINS, jax.random.PRNGKey(est_seed))
    return ScenarioState(
        **{k: jnp.asarray(v) for k, v in table.items()},
        wf_rows=jnp.asarray(wf_rows),
        hold=jnp.zeros(max_stages),
        canc_start=jnp.full(max_stages, jnp.inf),
        start_pending=jnp.zeros(max_stages, bool),
        chain_pending=jnp.zeros(max_stages, bool),
        rl_obs=jnp.zeros((max_stages, RL_FEATURES)),
        rl_act=jnp.full(max_stages, -1, jnp.int32),
        est=est,
        t=jnp.float32(now),
        free=jnp.float32(free_cores),
        total=jnp.float32(total_cores),
        policy=jnp.int32(policy),
        t0=jnp.float32(t0),
        busy_cs=jnp.float32(0.0),
        min_free=jnp.float32(free_cores),
        oh_cs=jnp.float32(0.0),
        misses=jnp.int32(0),
        repass=jnp.asarray(False),
        pred_greedy=jnp.asarray(pred_mode == "greedy"),
        steps=jnp.int32(0),
        fault_t=jnp.asarray(ft),
        fault_c=jnp.asarray(fc),
        fault_k=jnp.asarray(fk),
        fault_next=jnp.int32(0),
        cap_debt=jnp.float32(0.0),
        restarts=jnp.int32(0),
        restart_cs=jnp.float32(0.0),
        pilot_waste_cs=jnp.float32(pilot_waste_cs),
        trace=obs_trace.init(trace_capacity) if trace_capacity else None,
    )


def add_job(table: dict[str, np.ndarray], row: int, *, cores: float,
            duration: float, submit: float = np.inf, status: int = PENDING,
            start: float = np.inf, end: float = np.inf, start_dep: int = -1,
            wf_next: int = -1, is_wf: bool = False,
            pred_wait: float = 0.0) -> None:
    """Fill one host-side table row (scenario construction helper)."""
    table["submit"][row] = submit
    table["cores"][row] = cores
    table["duration"][row] = duration
    table["start"][row] = start
    table["end"][row] = end
    table["status"][row] = status
    table["start_dep"][row] = start_dep
    table["wf_next"][row] = wf_next
    table["is_wf"][row] = is_wf
    table["pred_wait"][row] = pred_wait
