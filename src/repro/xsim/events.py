"""Event-time advance + arrival/completion kernels + the `lax.scan` step.

One ``sim_step`` jumps to the next event time (earliest pending submission
or running-job completion), then applies, as masked array writes:

  completions → per-stage release hook → naive resubmit release →
  admissions → FCFS/backfill scheduling pass → stage-start hook →
  ASA chain hook.

Same-time cascades (e.g. a per-stage successor released *at* the
completion instant) simply consume the next scan step at an unchanged
``now`` — steps are cheap, so the step budget absorbs them. A scenario
with no remaining events makes every further step a no-op, which lets a
whole vmapped batch run the same static step count.

Policy hooks (kept here, not in policies.py, because they are part of the
per-event dataflow):

* PER_STAGE: when stage y completes, stage y+1's submit time becomes
  "now" — the sequential submit-on-completion loop of
  ``strategies.run_per_stage``.
* ASA / ASA-Naive *chain* hook: when stage y is first admitted at time
  s_y, the wait estimate a_y (stage 0 only; later stages were sampled at
  their predecessor's admission) and the successor's a_{y+1} are sampled
  from the scenario's LIVE Algorithm-1 estimator, the expected end
  E_y = max(s_y + a_y, E_{y-1}) + t_y chains forward, and stage y+1 is
  scheduled for max(now, E_y − a_{y+1}) — exactly the cascade of
  ``strategies.run_asa`` (§3.2, Fig. 4), now learning within the run.
* ASA / ASA-Naive *start* hook: when stage y starts, its observed queue
  wait feeds the tuned §4.5 estimator update (``asa.learn_wait_if``).
  Under ASA-Naive (no dependency support) an allocation granted before
  stage y−1's logical end either idles (short gaps ≤ 300 s, charged as
  OH core-seconds) or is CANCELLED and resubmitted once the predecessor
  completes (long gaps), charging the cancel latency as OH — mirroring
  ``strategies.run_asa(use_dependencies=False)``.
* Learned policy (``repro.rl``, policy id 4): same hooks as ASA-Naive
  (no-dependency world, estimator still learning), but the chain hook's
  wait estimates come from an MLP head over the same wait bins when a
  ``params`` pytree is threaded through the sweep — observations and
  chosen bins are recorded into the ``rl_obs``/``rl_act`` replay
  buffers. ``params=None`` statically elides the branch.

The start/chain hooks are drained INSIDE one ``sim_step``: a bounded
inner loop processes one (start, chain) pair per iteration — estimator
updates are inherently sequential (each consumes PRNG state), so the
pair-at-a-time order is exactly the order the old repass mechanism
produced and the cross-validation tests pin action-for-action — but a
multi-stage same-instant cascade no longer pays a full scan step
(completion scan + scheduling pass) per stage. The ``repass`` flag
survives for the one case that genuinely must reschedule mid-instant:
a naive/RL cancel frees cores (and possibly queues a same-instant
resubmission), so the drain exits and the next step re-runs the
scheduling pass at the unchanged ``now``, exactly as before.

``simulate`` runs the scan in K-step chunks under an outer
``lax.while_loop`` that exits once ``next_event_time`` is +inf — a
drained scenario stops paying for dead budget steps. Under ``vmap`` the
exit condition any-reduces across the batch (and per device under
``sharded_sweep``), and drained lanes step as exact no-ops, so the final
states stay bit-identical across chunk boundaries and device counts.

``sweep`` is the single-device fleet program (vmap over the batch);
``sharded_sweep`` shard_maps the same program's scenario axis over a 1-D
``scenarios`` device mesh — bit-identical, scenarios never communicate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.core import asa
from repro.core.bins import make_bins
from repro.obs import trace as obs_trace
from repro.runtime.fault import FAULT_DRAIN, FAULT_FAIL, FAULT_GROW
from repro.sched.strategies import (NAIVE_CANCEL_LATENCY_S,
                                    NAIVE_IDLE_THRESHOLD_S)
from repro.xsim import backfill
from repro.xsim.state import (ASA, ASA_NAIVE, CANCELLED, DONE, PENDING,
                              PER_STAGE, QUEUED, RL, RUNNING, ScenarioState)


def _asa_like(s: ScenarioState) -> jax.Array:
    """Policies that run the cascade hooks (chain + start + estimator)."""
    return (s.policy == ASA) | (s.policy == ASA_NAIVE) | (s.policy == RL)


def _naive_like(s: ScenarioState) -> jax.Array:
    """Policies without dependency support: early allocations idle or are
    cancelled/resubmitted (§4.5). The learned policy (repro.rl) lives in
    this world — the over-allocation OH is what makes its
    submit-lead-time problem non-degenerate."""
    return (s.policy == ASA_NAIVE) | (s.policy == RL)


def _job_stage(s: ScenarioState) -> jax.Array:
    """i32 (max_jobs,) workflow stage index per row; -1 for background."""
    n = s.status.shape[0]
    y = jnp.arange(s.wf_rows.shape[0], dtype=jnp.int32)
    tgt = jnp.where(s.wf_rows >= 0, s.wf_rows, n)   # n = drop
    return jnp.full(n, -1, jnp.int32).at[tgt].set(y, mode="drop")


def next_event_time(s: ScenarioState, naive: bool = True,
                    faults: bool = False) -> jax.Array:
    """Earliest pending submit, running end or unprocessed capacity fault;
    +inf when nothing remains.

    CANCELLED rows with a finite submit are naive resubmissions waiting
    for their corrected time; ``repass`` pins the next step to the current
    instant (mid-event estimator/cancel cascades). ``faults=False``
    (static) elides the fault-schedule term entirely."""
    submittable = s.status == PENDING
    if naive:
        submittable |= s.status == CANCELLED
    submits = jnp.where(submittable, s.submit, jnp.inf)
    ends = jnp.where(s.status == RUNNING, s.end, jnp.inf)
    nxt = jnp.minimum(jnp.min(submits), jnp.min(ends))
    if faults and s.fault_t.shape[0]:
        nf = s.fault_t.shape[0]
        i = jnp.clip(s.fault_next, 0, nf - 1)
        ft = jnp.where(s.fault_next < nf, s.fault_t[i], jnp.inf)
        nxt = jnp.minimum(nxt, ft)
    return jnp.where(s.repass, s.t, nxt)


def complete_jobs(s: ScenarioState, now, faults: bool = False
                  ) -> tuple[ScenarioState, jax.Array]:
    done = (s.status == RUNNING) & (s.end <= now)
    freed = jnp.sum(jnp.where(done, s.cores, 0.0))
    if faults:
        # draining nodes leave as their work completes: freed cores pay
        # outstanding drain debt before returning to the free pool
        pay = jnp.minimum(freed, s.cap_debt)
        s = s._replace(status=jnp.where(done, DONE, s.status),
                       free=s.free + freed - pay, total=s.total - pay,
                       cap_debt=s.cap_debt - pay)
    else:
        s = s._replace(status=jnp.where(done, DONE, s.status),
                       free=s.free + freed)
    return s, done


def admit_jobs(s: ScenarioState, now, naive: bool = True
               ) -> tuple[ScenarioState, jax.Array]:
    submittable = s.status == PENDING
    if naive:  # resubmitted CANCELLED rows re-enter the queue
        submittable |= s.status == CANCELLED
    adm = submittable & (s.submit <= now)
    s = s._replace(status=jnp.where(adm, QUEUED, s.status))
    return s, adm


def _release_per_stage(s: ScenarioState, newly_done, now) -> ScenarioState:
    """Stage y DONE ⇒ stage y+1 submitted now (submit-on-completion)."""
    n = s.status.shape[0]
    fire = newly_done & s.is_wf & (s.policy == PER_STAGE) & (s.wf_next >= 0)
    succ = jnp.where(fire, s.wf_next, n)  # n = drop
    submit = s.submit.at[succ].set(now, mode="drop")
    return s._replace(submit=submit)


def _release_naive_resubmit(s: ScenarioState, newly_done, now
                            ) -> tuple[ScenarioState, jax.Array, jax.Array]:
    """Stage y DONE ⇒ a CANCELLED successor is resubmitted now (§4.5).

    Also returns ``(fire, succ_c)`` — the firing predecessor lanes and
    their (clipped) successor rows — so ``sim_step`` can fold the
    RESUBMIT events into its fused trace append."""
    n = s.status.shape[0]
    succ_c = jnp.clip(s.wf_next, 0, n - 1)
    fire = (newly_done & s.is_wf & _naive_like(s)
            & (s.wf_next >= 0) & (s.status[succ_c] == CANCELLED))
    succ = jnp.where(fire, s.wf_next, n)
    submit = s.submit.at[succ].set(now, mode="drop")
    return s._replace(submit=submit), fire, succ_c


def _apply_faults(s: ScenarioState, now) -> ScenarioState:
    """Process every capacity-fault event due at ``now``, in schedule order.

    One bounded ``while_loop`` iteration per due event (events are
    time-sorted at build; ``fault_next`` is the cursor). Semantics, with
    the conservation invariant ``total − free == Σ running cores`` held
    through every transition:

    * GROW d: nodes join — ``total += d``, ``free += d``.
    * DRAIN d (clamped to the machine present): what is free leaves now;
      the remainder becomes ``cap_debt``, collected by ``complete_jobs``
      from freed cores as running work finishes — a graceful shrink, no
      job is disturbed.
    * FAIL d (clamped): nodes die now. Free cores cover what they can;
      the deficit is covered by killing running jobs — most recently
      started first (LIFO, the cheapest work to lose; ties broken by row
      index), a deterministic rule that keeps the scan reproducible.
      Killed jobs are requeued in place (RUNNING → QUEUED, original
      submit time kept, so they retain their FCFS seniority, like a
      Slurm requeue) and restart from scratch; the attempt's lost
      core-seconds accrue to ``restart_cs`` and ``restarts`` counts the
      kills — ``compare.metrics`` reports both.

    Completions at the same instant land BEFORE the fault (a job ending
    exactly when the node dies finished); admissions and the scheduling
    pass land after, so requeued jobs can restart within the same step
    when capacity allows. A dynamically empty schedule (all +inf) never
    enters the loop: bit-identical to the fault-free program.
    """
    nf = s.fault_t.shape[0]
    if nf == 0:
        return s
    n = s.status.shape[0]

    def cond(s: ScenarioState):
        i = jnp.clip(s.fault_next, 0, nf - 1)
        return (s.fault_next < nf) & (s.fault_t[i] <= now)

    def body(s: ScenarioState):
        i = jnp.clip(s.fault_next, 0, nf - 1)
        d = s.fault_c[i]
        k = s.fault_k[i]
        is_grow = k == FAULT_GROW
        is_drain = k == FAULT_DRAIN
        is_fail = k == FAULT_FAIL
        # you can never lose more cores than are physically present
        d_s = jnp.minimum(d, s.total)

        # DRAIN: remove what is free now, owe the rest
        rm = jnp.minimum(s.free, d_s)

        # FAIL: kill most-recently-started running jobs to cover the
        # deficit (free cores absorb the loss first)
        deficit = jnp.where(is_fail, d_s - s.free, 0.0)
        running = s.status == RUNNING
        order = jnp.argsort(jnp.where(running, -s.start, jnp.inf))
        c_sorted = jnp.where(running, s.cores, 0.0)[order]
        csum = jnp.cumsum(c_sorted)
        kill_sorted = (csum - c_sorted < deficit) & (c_sorted > 0.0)
        kill = (jnp.zeros(n, bool).at[order].set(kill_sorted)
                & running & is_fail)
        killed = jnp.sum(jnp.where(kill, s.cores, 0.0))
        lost_cs = jnp.sum(jnp.where(kill, s.cores * (now - s.start), 0.0))

        free = jnp.where(
            is_grow, s.free + d,
            jnp.where(is_drain, s.free - rm,
                      jnp.where(is_fail, s.free + killed - d_s, s.free)))
        total = jnp.where(
            is_grow, s.total + d,
            jnp.where(is_drain, s.total - rm,
                      jnp.where(is_fail, s.total - d_s, s.total)))

        tr = s.trace
        if tr is not None:
            row_i = jnp.arange(n, dtype=jnp.int32)
            tr = obs_trace.append_segments(
                tr, [(kill, obs_trace.EV_KILL, row_i, _job_stage(s),
                      s.cores)], t=now, policy=s.policy, step=s.steps)
        return s._replace(
            trace=tr,
            free=free,
            total=total,
            min_free=jnp.minimum(s.min_free, free),
            cap_debt=s.cap_debt + jnp.where(is_drain, d_s - rm, 0.0),
            status=jnp.where(kill, QUEUED, s.status),
            start=jnp.where(kill, jnp.inf, s.start),
            end=jnp.where(kill, jnp.inf, s.end),
            restarts=s.restarts + jnp.sum(kill).astype(jnp.int32),
            restart_cs=s.restart_cs + lost_cs,
            fault_next=s.fault_next + 1,
        )

    return jax.lax.while_loop(cond, body, s)


def _start_hook(s: ScenarioState, now, bins, naive: bool) -> ScenarioState:
    """Process ONE pending stage start: naive early handling + learning.

    Mirrors ``strategies.run_asa``'s ``on_started``: compute the gap to
    the predecessor's *logical* end (start + hold + duration); a positive
    gap under ASA-Naive is a miss — short gaps idle the allocation
    (OH += cores·gap), long gaps cancel it (OH += cores·latency) and park
    the row as CANCELLED until the predecessor completes. Every settled
    start feeds the tuned estimator with the observed queue wait.
    ``naive=False`` (a static, batch-level guarantee that no scenario runs
    ASA-Naive) elides the miss machinery at trace time.
    """
    n = s.status.shape[0]
    pending = s.start_pending
    any_p = jnp.any(pending)
    y = jnp.argmax(pending)                     # lowest pending stage
    row = jnp.clip(s.wf_rows[y], 0, n - 1)
    wait = now - s.submit[row]                  # observed queue wait

    if not naive:
        return s._replace(
            est=asa.learn_wait_if(s.est, bins, wait, any_p),
            start_pending=pending.at[y].set(False),
        )

    yp = jnp.maximum(y - 1, 0)
    prev_row = jnp.where(y > 0, s.wf_rows[yp], -1)
    pc = jnp.clip(prev_row, 0, n - 1)
    prev_started = (prev_row >= 0) & jnp.isfinite(s.start[pc])
    # a cancelled-not-yet-resubmitted predecessor still projects a logical
    # end from its aborted attempt (QueueSim's jobs[y−1] keeps start_time
    # until the resubmission replaces it)
    prev_cancelled = ((prev_row >= 0) & (s.status[pc] == CANCELLED)
                      & jnp.isfinite(s.canc_start[yp]))
    prev_logical = jnp.where(
        prev_row < 0, -jnp.inf,
        jnp.where(prev_started, s.start[pc] + s.hold[yp] + s.duration[pc],
                  jnp.where(prev_cancelled,
                            s.canc_start[yp] + s.duration[pc], jnp.inf)))
    early = prev_logical - now
    is_early = any_p & _naive_like(s) & (early > 0.0)
    do_cancel = is_early & (early > NAIVE_IDLE_THRESHOLD_S)
    do_hold = is_early & ~do_cancel
    do_learn = any_p & ~do_cancel

    est = asa.learn_wait_if(s.est, bins, wait, do_learn)

    prev_done = (prev_row >= 0) & (s.status[pc] == DONE)
    resub_t = jnp.where(prev_done, now, jnp.inf)
    tr = s.trace
    if tr is not None:
        tr = obs_trace.append_if(
            tr, do_cancel, kind=obs_trace.EV_CANCEL, t=now, job=row,
            stage=y.astype(jnp.int32), cores=s.cores[row],
            policy=s.policy, step=s.steps)
    return s._replace(
        trace=tr,
        est=est,
        start_pending=pending.at[y].set(False),
        hold=s.hold.at[y].set(jnp.where(do_hold, early, s.hold[y])),
        oh_cs=s.oh_cs
        + jnp.where(do_hold, s.cores[row] * early, 0.0)
        + jnp.where(do_cancel, s.cores[row] * NAIVE_CANCEL_LATENCY_S, 0.0),
        misses=s.misses + is_early.astype(jnp.int32),
        status=s.status.at[row].set(
            jnp.where(do_cancel, CANCELLED, s.status[row])),
        canc_start=s.canc_start.at[y].set(
            jnp.where(do_cancel, s.start[row], s.canc_start[y])),
        start=s.start.at[row].set(
            jnp.where(do_cancel, jnp.inf, s.start[row])),
        end=s.end.at[row].set(
            jnp.where(do_cancel, jnp.inf, s.end[row])),
        submit=s.submit.at[row].set(
            jnp.where(do_cancel, resub_t, s.submit[row])),
        free=s.free + jnp.where(do_cancel, s.cores[row], 0.0),
        # the ONLY remaining repass source: a cancellation changed the
        # machine (cores freed, row possibly resubmitted at this instant)
        # and the scheduler must run again before any further hook fires
        repass=s.repass | do_cancel,
    )


def _chain_hook(s: ScenarioState, now, bins, greedy, params=None,
                rl_mode: str = "sample") -> ScenarioState:
    """Process ONE pending stage admission: live-sample the §3.2 cascade.

    Stage y first admitted at s_y ⇒ (stage 0 only) sample a_0, fix
    E_y = max(s_y + a_y, E_{y-1}) + t_y, sample the successor's a_{y+1}
    from the live estimator and schedule it for max(now, E_y − a_{y+1}).

    ``params`` (a ``repro.rl.policy.PolicyParams`` pytree, or None)
    enables the learned-policy branch: scenarios with policy id 4 draw
    a_0/a_{y+1} from the MLP head over the same wait bins — observations
    and chosen bins are recorded into ``rl_obs``/``rl_act`` (the
    REINFORCE replay buffer) — while ASA scenarios in the same batch keep
    the estimator draw. ``params=None`` (static) elides the branch
    entirely: the pre-RL trace, bit for bit. ``rl_mode`` picks stochastic
    (training) vs argmax (evaluation) actions, statically.
    """
    n = s.status.shape[0]
    pending = s.chain_pending
    any_p = jnp.any(pending)
    y = jnp.argmax(pending)
    row = jnp.clip(s.wf_rows[y], 0, n - 1)

    # stage 0 samples its own wait estimate at submission (later stages
    # were sampled at their predecessor's admission, below)
    need_a0 = any_p & (y == 0)
    prev_row = jnp.where(y > 0, s.wf_rows[jnp.maximum(y - 1, 0)], -1)
    pc = jnp.clip(prev_row, 0, n - 1)
    prev_ee = jnp.where(prev_row < 0, -jnp.inf, s.expected_end[pc])
    succ = s.wf_next[row]
    sc = jnp.clip(succ, 0, n - 1)
    has_succ = any_p & (succ >= 0)

    def cascade_ee(s: ScenarioState, a0):
        """Stage y's settled a_y and expected end E_y for a given a_0.

        `now` IS the admission instant (events never skip a pending
        submit; repass steps hold time still); the stage's own submit
        entry may already have been rewritten by a same-instant naive
        cancel."""
        pw_row = jnp.where(need_a0, a0, s.pred_wait[row])
        return pw_row, jnp.maximum(now + pw_row, prev_ee) + s.duration[row]

    def asa_draws(s: ScenarioState):
        if greedy is True:
            # static greedy: both draws read the same (unchanged) MAP —
            # one argmax serves a0 and a1, and no PRNG is traced at all
            w_map = asa.map_wait(s.est, bins.astype(jnp.float32))
            return (s.est, jnp.where(need_a0, w_map, 0.0),
                    jnp.where(has_succ, w_map, 0.0))
        est, a0 = asa.sample_wait_if(s.est, bins, need_a0, greedy)
        est, a1 = asa.sample_wait_if(est, bins, has_succ, greedy)
        return est, a0, a1

    if params is None:
        est, a0, a1 = asa_draws(s)
    else:
        # trace-time import: repro.rl depends on xsim.grid → xsim.events,
        # so a module-level import here would be a cycle
        from repro.rl import features as rl_features
        from repro.rl import policy as rl_policy

        def rl_draws(s: ScenarioState):
            est = s.est
            if rl_mode == "sample":
                key, k0, k1 = jax.random.split(est.key, 3)
                est = est._replace(key=key)
            obs0 = rl_features.observe(s, y, row, prev_ee, now, bins)
            i0 = (rl_policy.act_greedy(params, obs0)
                  if rl_mode == "greedy"
                  else rl_policy.act_sample(params, obs0, k0))
            i0 = i0.astype(jnp.int32)
            a0 = jnp.where(need_a0, bins[i0], 0.0)
            _, ee = cascade_ee(s, a0)
            obs1 = rl_features.observe(s, y + 1, sc, ee, now, bins)
            i1 = (rl_policy.act_greedy(params, obs1)
                  if rl_mode == "greedy"
                  else rl_policy.act_sample(params, obs1, k1))
            i1 = i1.astype(jnp.int32)
            a1 = jnp.where(has_succ, bins[i1], 0.0)
            return est, a0, a1, obs0, obs1, i0, i1

        def asa_pad(s: ScenarioState):
            est, a0, a1 = asa_draws(s)
            zeros = jnp.zeros(rl_features.N_FEATURES, jnp.float32)
            return est, a0, a1, zeros, zeros, jnp.int32(-1), jnp.int32(-1)

        est, a0, a1, obs0, obs1, i0, i1 = jax.lax.cond(
            s.policy == RL, rl_draws, asa_pad, s)
        rec0 = (s.policy == RL) & need_a0
        rec1 = (s.policy == RL) & has_succ
        y1 = jnp.clip(y + 1, 0, s.wf_rows.shape[0] - 1)
        rl_obs = s.rl_obs.at[y].set(jnp.where(rec0, obs0, s.rl_obs[y]))
        rl_obs = rl_obs.at[y1].set(jnp.where(rec1, obs1, rl_obs[y1]))
        rl_act = s.rl_act.at[y].set(jnp.where(rec0, i0, s.rl_act[y]))
        rl_act = rl_act.at[y1].set(jnp.where(rec1, i1, rl_act[y1]))
        s = s._replace(rl_obs=rl_obs, rl_act=rl_act)

    pw_row, ee = cascade_ee(s, a0)

    pred_wait = s.pred_wait.at[row].set(pw_row)
    pred_wait = pred_wait.at[sc].set(
        jnp.where(has_succ, a1, pred_wait[sc]))
    return s._replace(
        est=est,
        chain_pending=pending.at[y].set(False),
        pred_wait=pred_wait,
        expected_end=s.expected_end.at[row].set(
            jnp.where(any_p, ee, s.expected_end[row])),
        submit=s.submit.at[sc].set(
            jnp.where(has_succ, jnp.maximum(now, ee - a1), s.submit[sc])),
    )


def _drain_hooks(s: ScenarioState, now, bins, greedy, naive: bool,
                 params, rl_mode: str) -> ScenarioState:
    """Drain every same-instant pending stage hook inside this step.

    One (start, chain) pair per iteration — the identical hook-call
    (and therefore PRNG-consumption) order the old one-pair-per-repass-
    step mechanism produced, minus the full scan step (completion scan +
    scheduling pass) each extra pair used to cost. The loop is bounded
    structurally: every iteration clears one ``start_pending`` and/or one
    ``chain_pending`` bit and never sets new ones (pendings are only
    raised at step level, from admissions and starts), so it runs at most
    ``max_stages`` times. A naive/RL cancel sets ``repass`` and exits:
    the machine changed mid-instant and must be rescheduled (a full
    same-time step) before later hooks may fire — matching the previous
    behaviour bit for bit on the cancel paths.
    """
    def cond(s: ScenarioState):
        return (~s.repass) & (jnp.any(s.start_pending)
                              | jnp.any(s.chain_pending))

    def body(s: ScenarioState):
        s = _start_hook(s, now, bins, naive)     # learn (+ naive miss) …
        return _chain_hook(s, now, bins, greedy, params, rl_mode)
        # … then predict, as the event-driven sim does

    return jax.lax.while_loop(cond, body, s)


def sim_step(s: ScenarioState, bins, *, bf_passes: int = backfill.BF_PASSES,
             freed_mode: str = "ref", pred_mode: str | None = None,
             naive: bool = True, params=None,
             rl_mode: str = "sample", faults: bool = False) -> ScenarioState:
    """One event step. ``pred_mode`` None reads the per-scenario
    ``pred_greedy`` flag (traced); ``"greedy"``/``"sample"`` stake the
    prediction rule out statically — the greedy fleet hot path then never
    traces the categorical draw. ``naive=False`` asserts (statically) that
    no scenario in the batch runs ASA-Naive (or the learned policy, which
    shares the cancel/resubmit world), eliding that machinery;
    ``grid.run_grid`` sets it from the grid's policy roster. ``params`` /
    ``rl_mode`` feed the learned-policy chain-hook branch (see
    ``_chain_hook``); ``params=None`` elides it. ``faults=False`` asserts
    (statically) that no scenario carries capacity-fault events, eliding
    the fault machinery (``_apply_faults`` + drain-debt collection) —
    ``grid.run_grid`` sets it from the grid's fault schedules."""
    if rl_mode not in ("sample", "greedy"):
        raise ValueError(f"unknown rl_mode {rl_mode!r}")
    greedy = {None: s.pred_greedy, "greedy": True,
              "sample": False}[pred_mode]
    nxt = next_event_time(s, naive, faults)
    now = jnp.where(jnp.isfinite(nxt), jnp.maximum(nxt, s.t), s.t)
    # utilization integral over (t, now] at the pre-event allocation
    busy_cs = s.busy_cs + (s.total - s.free) * (now - s.t)
    s = s._replace(t=now, busy_cs=busy_cs, repass=jnp.asarray(False),
                   # drained lanes don't count: `steps` is the
                   # events-executed profile signal vs. the n_steps budget
                   steps=s.steps + jnp.isfinite(nxt).astype(jnp.int32))
    s, newly_done = complete_jobs(s, now, faults)
    s = _release_per_stage(s, newly_done, now)
    resub_fire = resub_succ = None
    if naive:
        s, resub_fire, resub_succ = _release_naive_resubmit(
            s, newly_done, now)
    if faults:
        # after completions (a job ending at the fault instant finished),
        # before admissions/scheduling (which see post-fault capacity)
        s = _apply_faults(s, now)
    s, newly_admitted = admit_jobs(s, now, naive)
    # first admissions of ASA/naive stages queue a chain-hook event
    # (the -inf expected_end sentinel keeps resubmissions from re-firing)
    rows = jnp.clip(s.wf_rows, 0, s.status.shape[0] - 1)
    stage_ok = (s.wf_rows >= 0) & _asa_like(s)
    s = s._replace(chain_pending=s.chain_pending | (
        stage_ok & newly_admitted[rows] & jnp.isneginf(s.expected_end[rows])))
    pre_start = s.start
    s = backfill.schedule_pass(s, bf_passes=bf_passes, freed_mode=freed_mode)
    started = (s.status == RUNNING) & jnp.isinf(pre_start)
    if s.trace is not None:
        # one fused ring write per step, in event order: finishes,
        # naive resubmissions, admissions, starts (cancels are appended
        # from the start hook itself, inside the drain)
        n = s.status.shape[0]
        row_i = jnp.arange(n, dtype=jnp.int32)
        stg = _job_stage(s)
        segs = [(newly_done, obs_trace.EV_FINISH, row_i, stg, s.cores)]
        if naive:
            segs.append((resub_fire, obs_trace.EV_RESUBMIT, resub_succ,
                         stg[resub_succ], s.cores[resub_succ]))
        segs.append((newly_admitted, obs_trace.EV_SUBMIT, row_i, stg,
                     s.cores))
        segs.append((started, obs_trace.EV_START, row_i, stg, s.cores))
        s = s._replace(trace=obs_trace.append_segments(
            s.trace, segs, t=now, policy=s.policy, step=s.steps))
    s = s._replace(start_pending=s.start_pending | (
        stage_ok & started[rows]))
    return _drain_hooks(s, now, bins, greedy, naive, params, rl_mode)


CHUNK_STEPS = 8  # scan-chunk size between drain checks (see `simulate`)


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "chunk_steps", "bf_passes",
                                    "freed_mode", "pred_mode", "naive",
                                    "rl_mode", "faults"))
def simulate(s: ScenarioState, *, n_steps: int,
             chunk_steps: int = CHUNK_STEPS,
             bf_passes: int = backfill.BF_PASSES,
             freed_mode: str = "ref", pred_mode: str | None = None,
             naive: bool = True, params=None,
             rl_mode: str = "sample", faults: bool = False) -> ScenarioState:
    """Run up to ~``n_steps`` event steps, stopping early once drained.

    The scan is split into a static ``n_steps % chunk_steps`` remainder
    scan (run first, while there is certainly work) followed by
    ``chunk_steps``-step chunks under an outer ``lax.while_loop`` that
    exits as soon as ``next_event_time`` hits +inf — a drained scenario
    stops paying for dead budget steps, and at most exactly ``n_steps``
    steps ever run. A drained ``sim_step`` is an exact no-op (time,
    PRNG, every table field), so the early exit cannot change the
    result: final states are bit-identical to the unchunked program for
    every chunk size — in the truncation regime too, where both run
    exactly ``n_steps`` steps in the same order — and under
    ``vmap``/``shard_map`` (where the exit condition any-reduces over
    the per-device batch) for every device count. ``chunk_steps=0``
    disables chunking: one static ``n_steps`` scan, the pre-chunking
    program.
    """
    m = s.est.log_p.shape[-1]
    bins = jnp.asarray(make_bins(m), jnp.float32)

    def body(s, _):
        return sim_step(s, bins, bf_passes=bf_passes, freed_mode=freed_mode,
                        pred_mode=pred_mode, naive=naive, params=params,
                        rl_mode=rl_mode, faults=faults), None

    if chunk_steps <= 0:
        s, _ = jax.lax.scan(body, s, None, length=n_steps)
        return s

    n_chunks, rem = divmod(n_steps, chunk_steps)
    if rem:
        s, _ = jax.lax.scan(body, s, None, length=rem)

    def chunk_cond(carry):
        s, i = carry
        return (i < n_chunks) & jnp.isfinite(
            next_event_time(s, naive, faults))

    def chunk_body(carry):
        s, i = carry
        s, _ = jax.lax.scan(body, s, None, length=chunk_steps)
        return s, i + 1

    s, _ = jax.lax.while_loop(chunk_cond, chunk_body, (s, jnp.int32(0)))
    return s


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "chunk_steps", "bf_passes",
                                    "freed_mode", "pred_mode", "naive",
                                    "rl_mode", "faults"))
def sweep(batched: ScenarioState, *, n_steps: int,
          chunk_steps: int = CHUNK_STEPS,
          bf_passes: int = backfill.BF_PASSES,
          freed_mode: str = "ref", pred_mode: str | None = None,
          naive: bool = True, params=None,
          rl_mode: str = "sample", faults: bool = False) -> ScenarioState:
    """The fleet program: vmap(simulate) over a batched ScenarioState.

    ``freed_mode="tpu"`` routes the reservation scan through the Pallas
    kernel (vmap batches it into one (B, N) grid program). ``params``
    (the learned policy head's weights) is closed over, so it broadcasts
    across the fleet rather than being vmapped. The chunked drain exit
    any-reduces over the batch: the sweep stops as soon as EVERY scenario
    is out of events.
    """
    return jax.vmap(
        lambda s: simulate(s, n_steps=n_steps, chunk_steps=chunk_steps,
                           bf_passes=bf_passes, freed_mode=freed_mode,
                           pred_mode=pred_mode, naive=naive, params=params,
                           rl_mode=rl_mode, faults=faults)
    )(batched)


@functools.lru_cache(maxsize=None)
def _sharded_sweep_fn(mesh, n_steps, chunk_steps, bf_passes, freed_mode,
                      pred_mode, naive, rl_mode, faults, with_params):
    """Compiled shard_map(sweep) for one (mesh, static-config) cell.

    Cached so repeated sweeps (warm_fleet rounds, RL iterations, bench
    reps) reuse one jitted program — the same role ``jax.jit``'s own
    cache plays on the vmap path. ``chunk_steps`` is part of the key:
    each chunking choice is its own compiled program (the early-exit
    while_loop structure depends on it).
    """
    from repro.parallel import fleet as pfleet

    spec = pfleet.shard_spec()

    def block(shard: ScenarioState, params):
        return sweep(shard, n_steps=n_steps, chunk_steps=chunk_steps,
                     bf_passes=bf_passes, freed_mode=freed_mode,
                     pred_mode=pred_mode, naive=naive, params=params,
                     rl_mode=rl_mode, faults=faults)

    if with_params:
        fn = shard_map(block, mesh=mesh,
                       in_specs=(spec, pfleet.replicated_spec()),
                       out_specs=spec, check_rep=False)
    else:
        fn = shard_map(lambda shard: block(shard, None), mesh=mesh,
                       in_specs=(spec,), out_specs=spec, check_rep=False)
    return jax.jit(fn)


def sharded_sweep(batched: ScenarioState, *, mesh, n_steps: int,
                  chunk_steps: int = CHUNK_STEPS,
                  bf_passes: int = backfill.BF_PASSES,
                  freed_mode: str = "ref", pred_mode: str | None = None,
                  naive: bool = True, params=None,
                  rl_mode: str = "sample",
                  faults: bool = False) -> ScenarioState:
    """``sweep`` split over the devices of a 1-D ``scenarios`` mesh.

    Each device runs the plain vmapped program on its contiguous block of
    scenarios (``params`` replicated), so the gathered result is
    bit-identical to the single-device ``sweep`` — pinned by
    tests/test_xsim_sharded.py. The chunked drain exit is *per device*
    (each block's while_loop any-reduces over its own lanes): a device
    whose scenarios drain early stops stepping while busier devices run
    on, and because drained steps are exact no-ops the gathered result
    still matches the vmap path bit for bit. Batch sizes not divisible by
    the shard count are padded with copies of scenario 0 (a valid row, so
    the pad lanes run the same control flow) and the pad rows are sliced
    off the gathered output. Build the mesh with
    ``repro.launch.mesh.make_scenarios_mesh``.
    """
    from repro.parallel import fleet as pfleet

    n_shards = mesh.shape[pfleet.SCENARIO_AXIS]
    b = pfleet.batch_size(batched)
    padded, _mask = pfleet.pad_batch(batched, n_shards)
    fn = _sharded_sweep_fn(mesh, n_steps, chunk_steps, bf_passes,
                           freed_mode, pred_mode, naive, rl_mode, faults,
                           params is not None)
    out = fn(padded, params) if params is not None else fn(padded)
    return pfleet.unpad(out, b)
