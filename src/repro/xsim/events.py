"""Event-time advance + arrival/completion kernels + the `lax.scan` step.

One ``sim_step`` jumps to the next event time (earliest pending submission
or running-job completion), then applies, as masked array writes:

  completions → per-stage release hook → admissions → ASA chain hook →
  FCFS/backfill scheduling pass.

Same-time cascades (e.g. a per-stage successor released *at* the
completion instant) simply consume the next scan step at an unchanged
``now`` — steps are cheap, so the step budget absorbs them. A scenario
with no remaining events makes every further step a no-op, which lets a
whole vmapped batch run the same static step count.

Policy hooks (kept here, not in policies.py, because they are part of the
per-event dataflow):

* PER_STAGE: when stage y completes, stage y+1's submit time becomes
  "now" — the sequential submit-on-completion loop of
  ``strategies.run_per_stage``.
* ASA: when stage y is *admitted* (pro-actively submitted) at time s_y,
  its expected end  E_y = max(s_y + a_y, E_{y-1}) + t_y  chains forward
  and stage y+1 is scheduled for  max(now, E_y − a_{y+1})  — exactly the
  cascade of ``strategies.run_asa`` (§3.2, Fig. 4), with the sampled wait
  estimates a_y frozen at scenario build time (see policies.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.xsim import backfill
from repro.xsim.state import (ASA, DONE, PENDING, PER_STAGE, QUEUED, RUNNING,
                              ScenarioState)


def next_event_time(s: ScenarioState) -> jax.Array:
    """Earliest pending submit or running end; +inf when nothing remains."""
    submits = jnp.where(s.status == PENDING, s.submit, jnp.inf)
    ends = jnp.where(s.status == RUNNING, s.end, jnp.inf)
    return jnp.minimum(jnp.min(submits), jnp.min(ends))


def complete_jobs(s: ScenarioState, now) -> tuple[ScenarioState, jax.Array]:
    done = (s.status == RUNNING) & (s.end <= now)
    freed = jnp.sum(jnp.where(done, s.cores, 0.0))
    s = s._replace(status=jnp.where(done, DONE, s.status), free=s.free + freed)
    return s, done


def admit_jobs(s: ScenarioState, now) -> tuple[ScenarioState, jax.Array]:
    adm = (s.status == PENDING) & (s.submit <= now)
    s = s._replace(status=jnp.where(adm, QUEUED, s.status))
    return s, adm


def _release_per_stage(s: ScenarioState, newly_done, now) -> ScenarioState:
    """Stage y DONE ⇒ stage y+1 submitted now (submit-on-completion)."""
    n = s.status.shape[0]
    fire = newly_done & s.is_wf & (s.policy == PER_STAGE) & (s.wf_next >= 0)
    succ = jnp.where(fire, s.wf_next, n)  # n = drop
    submit = s.submit.at[succ].set(now, mode="drop")
    return s._replace(submit=submit)


def _asa_chain(s: ScenarioState, newly_admitted, now) -> ScenarioState:
    """Stage y admitted ⇒ fix E_y and schedule stage y+1 pro-actively."""
    n = s.status.shape[0]
    fire = newly_admitted & s.is_wf & (s.policy == ASA)
    dep = jnp.clip(s.start_dep, 0, n - 1)
    prev_ee = jnp.where(s.start_dep < 0, -jnp.inf, s.expected_end[dep])
    ee = jnp.maximum(s.submit + s.pred_wait, prev_ee) + s.duration
    expected_end = jnp.where(fire, ee, s.expected_end)
    succ_ok = fire & (s.wf_next >= 0)
    succ = jnp.where(succ_ok, s.wf_next, n)
    succ_submit = jnp.maximum(now, ee - s.pred_wait[jnp.clip(s.wf_next, 0, n - 1)])
    submit = s.submit.at[succ].set(
        jnp.where(succ_ok, succ_submit, 0.0), mode="drop")
    return s._replace(expected_end=expected_end, submit=submit)


def sim_step(s: ScenarioState, *, bf_passes: int = backfill.BF_PASSES,
             freed_mode: str = "ref") -> ScenarioState:
    nxt = next_event_time(s)
    now = jnp.where(jnp.isfinite(nxt), jnp.maximum(nxt, s.t), s.t)
    # utilization integral over (t, now] at the pre-event allocation
    busy_cs = s.busy_cs + (s.total - s.free) * (now - s.t)
    s = s._replace(t=now, busy_cs=busy_cs)
    s, newly_done = complete_jobs(s, now)
    s = _release_per_stage(s, newly_done, now)
    s, newly_admitted = admit_jobs(s, now)
    s = _asa_chain(s, newly_admitted, now)
    return backfill.schedule_pass(s, bf_passes=bf_passes,
                                  freed_mode=freed_mode)


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "bf_passes", "freed_mode"))
def simulate(s: ScenarioState, *, n_steps: int,
             bf_passes: int = backfill.BF_PASSES,
             freed_mode: str = "ref") -> ScenarioState:
    """Run ``n_steps`` event steps (idempotent once events are drained)."""
    def body(s, _):
        return sim_step(s, bf_passes=bf_passes, freed_mode=freed_mode), None

    s, _ = jax.lax.scan(body, s, None, length=n_steps)
    return s


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "bf_passes", "freed_mode"))
def sweep(batched: ScenarioState, *, n_steps: int,
          bf_passes: int = backfill.BF_PASSES,
          freed_mode: str = "ref") -> ScenarioState:
    """The fleet program: vmap(simulate) over a batched ScenarioState.

    ``freed_mode="tpu"`` routes the reservation scan through the Pallas
    kernel (vmap batches it into one (B, N) grid program).
    """
    return jax.vmap(
        lambda s: simulate(s, n_steps=n_steps, bf_passes=bf_passes,
                           freed_mode=freed_mode)
    )(batched)
