"""Vectorized FCFS + EASY-backfill scheduling pass.

Mirrors ``QueueSim._schedule_pass`` with masked array ops:

  1. *FCFS prefix start* — eligible queued jobs sorted by (submit, row);
     because core counts are positive the "start from the front while it
     fits" loop is exactly the maximal prefix whose core cumsum fits in
     the free cores, so one sort + cumsum starts any number of head jobs.
  2. *Reservation* — when the queue head does not fit, compute its
     earliest feasible start (shadow time) and the spare cores at that
     moment. This is the hot O(n²) scan over the running-job table; a
     Pallas kernel (`freed_matrix`) computes it batched on accelerator,
     with a pure-jnp reference used on CPU.
  3. *Backfill loop* — a short `fori_loop`; each pass starts the first
     (FCFS order) queued job that fits now AND either drains before the
     shadow time or fits inside the reservation's spare cores. QueueSim
     starts arbitrarily many backfill jobs per pass; a bounded loop is the
     vectorized approximation (any job missed here is reconsidered at the
     very next event, so with the default 16 passes the divergence is
     rarely observable).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.xsim.state import DONE, QUEUED, RUNNING, ScenarioState

BF_PASSES = 16  # backfill starts per scheduling pass (QueueSim: unbounded)


# ---------------------------------------------------------------- helpers
def eligible_mask(s: ScenarioState) -> jax.Array:
    """Queued jobs whose afterok dependency (if any) has completed."""
    dep = jnp.clip(s.start_dep, 0, s.status.shape[0] - 1)
    dep_done = jnp.where(s.start_dep < 0, True, s.status[dep] == DONE)
    return (s.status == QUEUED) & dep_done


def fcfs_order(s: ScenarioState, mask: jax.Array):
    """Stable FCFS ordering of ``mask`` jobs by (submit, row index).

    Returns (order, rank): ``order`` lists job rows FCFS-first (masked-out
    rows pushed to the back), ``rank[j]`` is row j's queue position.
    """
    key = jnp.where(mask, s.submit, jnp.inf)
    order = jnp.argsort(key)                 # stable → row index tiebreak
    rank = jnp.argsort(order)
    return order, rank


# ------------------------------------------------- reservation (the O(n²))
def _freed_math(ends, cores, running):
    """freed[i] = cores released once every running job ending ≤ end_i ends."""
    e = jnp.where(running, ends, jnp.inf)
    c = jnp.where(running, cores, 0.0)
    before = (e[None, :] <= e[:, None]) & running[None, :]
    return jnp.sum(jnp.where(before, c[None, :], 0.0), axis=1)


def _freed_kernel(ends_ref, cores_ref, run_ref, freed_ref):
    e = ends_ref[0]
    r = run_ref[0] > 0
    c = jnp.where(r, cores_ref[0], 0.0)
    e = jnp.where(r, e, jnp.inf)
    before = (e[None, :] <= e[:, None]) & r[None, :]
    freed_ref[0] = jnp.sum(jnp.where(before, c[None, :], 0.0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def freed_matrix(ends, cores, running, *, interpret: bool = False):
    """Batched Pallas path for `_freed_math`: (B, N) tables → (B, N) freed.

    One grid program per scenario row; the (N, N) end-time comparison
    matrix lives in VMEM and reduces on the VPU. Used on TPU (or under
    ``interpret`` for tests); the sweep's default CPU path inlines the
    jnp reference, keeping `schedule_pass` trivially vmap-able.
    """
    B, N = ends.shape
    return pl.pallas_call(
        _freed_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(ends.astype(jnp.float32), cores.astype(jnp.float32),
      running.astype(jnp.float32))


def freed_vector(ends, cores, running, *, mode: str = "ref"):
    """Dispatch the freed-cores scan: jnp reference or the Pallas kernel.

    ``ref``: inline jnp (the CPU default — trivially vmap-able).
    ``interpret``/``tpu``: the Pallas kernel, run single-scenario; under
    ``jax.vmap`` the batching rule turns it into the (B, N) grid.
    """
    if mode == "ref":
        return _freed_math(ends, cores, running)
    if mode in ("interpret", "tpu"):
        return freed_matrix(ends[None, :], cores[None, :], running[None, :],
                            interpret=(mode == "interpret"))[0]
    raise ValueError(f"unknown freed mode {mode!r}")


def reservation(ends, cores, running, free, head_cores, freed=None):
    """EASY reservation: (shadow_time, spare_cores_at_shadow) for the head.

    ``freed`` may be precomputed (e.g. by the Pallas kernel); otherwise the
    jnp reference is used. Semantics match ``QueueSim._reservation``: walk
    running jobs by end time until the head fits; no feasible point → +inf.
    """
    if freed is None:
        freed = _freed_math(ends, cores, running)
    e = jnp.where(running, ends, jnp.inf)
    ok = running & (free + freed >= head_cores)
    pick = jnp.argmin(jnp.where(ok, e, jnp.inf))
    any_ok = jnp.any(ok)
    shadow = jnp.where(any_ok, e[pick], jnp.inf)
    extra = jnp.where(any_ok, free + freed[pick] - head_cores, 0.0)
    return shadow, extra


# ------------------------------------------------------- scheduling pass
def _start_rows(s: ScenarioState, mask: jax.Array, now) -> ScenarioState:
    started_cores = jnp.sum(jnp.where(mask, s.cores, 0.0))
    free = s.free - started_cores
    return s._replace(
        status=jnp.where(mask, RUNNING, s.status),
        start=jnp.where(mask, now, s.start),
        end=jnp.where(mask, now + s.duration, s.end),
        free=free,
        min_free=jnp.minimum(s.min_free, free),
    )


def schedule_pass(s: ScenarioState, *, bf_passes: int = BF_PASSES,
                  freed_mode: str = "ref") -> ScenarioState:
    """One FCFS + EASY-backfill pass at the current sim time ``s.t``."""
    now = s.t
    n = s.status.shape[0]

    # 1. maximal FCFS prefix that fits ------------------------------------
    elig = eligible_mask(s)
    order, rank = fcfs_order(s, elig)
    sorted_elig = elig[order]
    sorted_cores = jnp.where(sorted_elig, s.cores[order], 0.0)
    csum = jnp.cumsum(sorted_cores)
    fits = sorted_elig & (csum <= s.free)
    # cores > 0 ⇒ csum monotone ⇒ `fits` is automatically a prefix
    start_mask = jnp.zeros(n, bool).at[order].set(fits)
    s = _start_rows(s, start_mask, now)

    # 2. reservation for the head (first eligible job that did not fit) ---
    elig = eligible_mask(s)
    n_elig = jnp.sum(elig)
    head = jnp.argmin(jnp.where(elig, rank, n))   # FCFS-first leftover
    has_head = n_elig > 0
    running = s.status == RUNNING
    freed = freed_vector(s.end, s.cores, running, mode=freed_mode)
    shadow, extra = reservation(
        s.end, s.cores, running, s.free,
        jnp.where(has_head, s.cores[head], 0.0), freed=freed)

    # 3. bounded backfill loop -------------------------------------------
    def body(_, carry):
        s, extra = carry
        elig = eligible_mask(s)
        cand = (elig & (jnp.arange(n) != head) & (s.cores <= s.free)
                & ((now + s.duration <= shadow) | (s.cores <= extra)))
        pick = jnp.argmin(jnp.where(cand, rank, n))
        do = jnp.any(cand) & has_head
        pick_mask = (jnp.arange(n) == pick) & do
        # QueueSim decrements the reservation's spare only when the job
        # rode in on it (fits_in_extra), even if it also drains in time
        used_extra = jnp.where(do & (s.cores[pick] <= extra),
                               s.cores[pick], 0.0)
        return _start_rows(s, pick_mask, now), extra - used_extra

    s, _ = jax.lax.fori_loop(0, bf_passes, body, (s, extra))
    return s
