"""Vectorized FCFS + EASY-backfill scheduling pass.

Mirrors ``QueueSim._schedule_pass`` with masked array ops:

  1. *FCFS prefix start* — eligible queued jobs sorted by (submit, row);
     because core counts are positive the "start from the front while it
     fits" loop is exactly the maximal prefix whose core cumsum fits in
     the free cores, so one sort + cumsum starts any number of head jobs.
  2. *Reservation* — when the queue head does not fit, compute its
     earliest feasible start (shadow time) and the spare cores at that
     moment. The hot quantity is freed[i] = Σ cores of running jobs
     ending ≤ end_i; the default path computes it in O(n log n) by
     sorting the running jobs by end time, cumsum-ing their cores and
     gathering the cumsum at the last index of each end-time tie run
     (``_freed_sorted``). The original O(n²) pairwise comparison stays
     available as ``freed_mode="ref_n2"`` for differential checks — the
     two agree bit-for-bit on the integer-valued core counts every grid
     uses (both sums are exact integers below 2**24). A Pallas kernel
     (`freed_matrix`) runs the same sorted formulation batched on
     accelerator: XLA sorts the (B, N) tables, the kernel does the O(n)
     scan portion (cores cumsum + tie-aware backward fill) in VMEM, and
     the result scatters back through the inverse permutation.
  3. *Backfill loop* — a short `fori_loop`; each pass starts the first
     (FCFS order) queued job that fits now AND either drains before the
     shadow time or fits inside the reservation's spare cores. QueueSim
     starts arbitrarily many backfill jobs per pass; a bounded loop is the
     vectorized approximation (any job missed here is reconsidered at the
     very next event, so with the default 16 passes the divergence is
     rarely observable).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.xsim.state import DONE, QUEUED, RUNNING, ScenarioState

BF_PASSES = 16  # backfill starts per scheduling pass (QueueSim: unbounded)

FREED_MODES = ("ref", "ref_n2", "interpret", "tpu")


# ---------------------------------------------------------------- helpers
def eligible_mask(s: ScenarioState) -> jax.Array:
    """Queued jobs whose afterok dependency (if any) has completed."""
    dep = jnp.clip(s.start_dep, 0, s.status.shape[0] - 1)
    dep_done = jnp.where(s.start_dep < 0, True, s.status[dep] == DONE)
    return (s.status == QUEUED) & dep_done


def fcfs_order(s: ScenarioState, mask: jax.Array):
    """Stable FCFS ordering of ``mask`` jobs by (submit, row index).

    Returns (order, rank): ``order`` lists job rows FCFS-first (masked-out
    rows pushed to the back), ``rank[j]`` is row j's queue position.
    """
    key = jnp.where(mask, s.submit, jnp.inf)
    order = jnp.argsort(key)                 # stable → row index tiebreak
    rank = jnp.argsort(order)
    return order, rank


# ------------------------------------------------- reservation (the scan)
def _freed_math(ends, cores, running):
    """O(n²) reference: freed[i] = cores released once every running job
    ending ≤ end_i ends. Kept behind ``freed_mode="ref_n2"`` so the
    sorted fast path can always be differentially checked against it."""
    e = jnp.where(running, ends, jnp.inf)
    c = jnp.where(running, cores, 0.0)
    before = (e[None, :] <= e[:, None]) & running[None, :]
    return jnp.sum(jnp.where(before, c[None, :], 0.0), axis=1)


def _freed_sorted(ends, cores, running):
    """O(n log n) freed-cores scan: argsort + cores-cumsum + tie gather.

    Sort the (masked) end times; the cores cumsum at sorted position k is
    the total released by the first k+1 enders, so freed[i] is the cumsum
    at the *last* sorted index whose end ≤ end_i — ``searchsorted(...,
    side="right") − 1`` lands exactly there, ties included. Non-running
    rows are masked to end=+inf / cores=0, reproducing the reference's
    convention (their freed value is the whole running total). Exact (not
    just close) for integer-valued core counts: both this cumsum and the
    reference's row-order sum are exact integer arithmetic below 2**24.
    """
    e = jnp.where(running, ends, jnp.inf)
    c = jnp.where(running, cores, 0.0)
    order = jnp.argsort(e)
    csum = jnp.cumsum(c[order])
    cnt = jnp.searchsorted(e[order], e, side="right")  # ≥ 1: e_i is present
    return csum[cnt - 1]


def _freed_sorted_kernel(ends_ref, cores_ref, freed_ref):
    """Scan portion of the sorted formulation, on PRE-SORTED (1, N) rows.

    freed_sorted[k] must be the cores cumsum at the last index of k's
    end-time tie run. With ``csum`` nondecreasing, that value is the
    minimum of ``csum`` over the run-*last* positions at or after k — a
    suffix-min over ``where(is_last, csum, +inf)``, computed with a
    log₂(N)-step shift-and-min doubling loop (static slices + concats:
    no gathers, no negative strides — VPU-friendly and interpretable).
    """
    e = ends_ref[...]                      # (1, N), sorted ascending
    csum = jnp.cumsum(cores_ref[...], axis=-1)
    n = e.shape[-1]
    nxt = jnp.concatenate(
        [e[:, 1:], jnp.full((1, 1), -jnp.inf, e.dtype)], axis=-1)
    is_last = e != nxt                     # last element of each tie run
    v = jnp.where(is_last, csum, jnp.inf)
    k = 1
    while k < n:                           # static unroll: ⌈log₂ N⌉ steps
        shifted = jnp.concatenate(
            [v[:, k:], jnp.full((1, k), jnp.inf, v.dtype)], axis=-1)
        v = jnp.minimum(v, shifted)
        k *= 2
    freed_ref[...] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def freed_matrix(ends, cores, running, *, interpret: bool = False):
    """Batched Pallas path for the sorted scan: (B, N) tables → (B, N).

    XLA sorts each row (its sort is the part not worth hand-writing), one
    grid program per scenario row runs the O(n) cumsum + tie-aware
    suffix-min in VMEM, and the result scatters back through the inverse
    permutation. Used on TPU (or under ``interpret`` for tests); the
    sweep's default CPU path inlines the jnp sorted reference, keeping
    `schedule_pass` trivially vmap-able. Bit-identical to
    ``_freed_sorted`` (and to the O(n²) reference on integer cores).
    """
    B, N = ends.shape
    e = jnp.where(running.astype(bool), ends, jnp.inf).astype(jnp.float32)
    c = jnp.where(running.astype(bool), cores, 0.0).astype(jnp.float32)
    order = jnp.argsort(e, axis=1)
    e_s = jnp.take_along_axis(e, order, axis=1)
    c_s = jnp.take_along_axis(c, order, axis=1)
    freed_s = pl.pallas_call(
        _freed_sorted_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(e_s, c_s)
    inv = jnp.argsort(order, axis=1)
    return jnp.take_along_axis(freed_s, inv, axis=1)


def freed_vector(ends, cores, running, *, mode: str = "ref"):
    """Dispatch the freed-cores scan.

    ``ref``: the sorted O(n log n) jnp path (the CPU default — trivially
    vmap-able). ``ref_n2``: the original O(n²) pairwise reference, kept
    for differential checks. ``interpret``/``tpu``: the sorted Pallas
    kernel, run single-scenario; under ``jax.vmap`` the batching rule
    turns it into the (B, N) grid.
    """
    if mode == "ref":
        return _freed_sorted(ends, cores, running)
    if mode == "ref_n2":
        return _freed_math(ends, cores, running)
    if mode in ("interpret", "tpu"):
        return freed_matrix(ends[None, :], cores[None, :], running[None, :],
                            interpret=(mode == "interpret"))[0]
    raise ValueError(f"unknown freed mode {mode!r} (want one of "
                     f"{FREED_MODES})")


def reservation(ends, cores, running, free, head_cores, freed=None):
    """EASY reservation: (shadow_time, spare_cores_at_shadow) for the head.

    ``freed`` may be precomputed (e.g. by the Pallas kernel); otherwise
    the sorted jnp path is used. Semantics match
    ``QueueSim._reservation``: walk running jobs by end time until the
    head fits; no feasible point → +inf.
    """
    if freed is None:
        freed = _freed_sorted(ends, cores, running)
    e = jnp.where(running, ends, jnp.inf)
    ok = running & (free + freed >= head_cores)
    pick = jnp.argmin(jnp.where(ok, e, jnp.inf))
    any_ok = jnp.any(ok)
    shadow = jnp.where(any_ok, e[pick], jnp.inf)
    extra = jnp.where(any_ok, free + freed[pick] - head_cores, 0.0)
    return shadow, extra


# ------------------------------------------------------- scheduling pass
def _start_rows(s: ScenarioState, mask: jax.Array, now) -> ScenarioState:
    started_cores = jnp.sum(jnp.where(mask, s.cores, 0.0))
    free = s.free - started_cores
    return s._replace(
        status=jnp.where(mask, RUNNING, s.status),
        start=jnp.where(mask, now, s.start),
        end=jnp.where(mask, now + s.duration, s.end),
        free=free,
        min_free=jnp.minimum(s.min_free, free),
    )


def schedule_pass(s: ScenarioState, *, bf_passes: int = BF_PASSES,
                  freed_mode: str = "ref") -> ScenarioState:
    """One FCFS + EASY-backfill pass at the current sim time ``s.t``."""
    now = s.t
    n = s.status.shape[0]

    # 1. maximal FCFS prefix that fits ------------------------------------
    elig = eligible_mask(s)
    order, rank = fcfs_order(s, elig)
    sorted_elig = elig[order]
    sorted_cores = jnp.where(sorted_elig, s.cores[order], 0.0)
    csum = jnp.cumsum(sorted_cores)
    fits = sorted_elig & (csum <= s.free)
    # cores > 0 ⇒ csum monotone ⇒ `fits` is automatically a prefix
    start_mask = jnp.zeros(n, bool).at[order].set(fits)
    s = _start_rows(s, start_mask, now)

    # 2. reservation for the head (first eligible job that did not fit) ---
    elig = eligible_mask(s)
    n_elig = jnp.sum(elig)
    head = jnp.argmin(jnp.where(elig, rank, n))   # FCFS-first leftover
    has_head = n_elig > 0
    running = s.status == RUNNING
    freed = freed_vector(s.end, s.cores, running, mode=freed_mode)
    shadow, extra = reservation(
        s.end, s.cores, running, s.free,
        jnp.where(has_head, s.cores[head], 0.0), freed=freed)

    # 3. bounded backfill loop -------------------------------------------
    def body(_, carry):
        s, extra = carry
        elig = eligible_mask(s)
        cand = (elig & (jnp.arange(n) != head) & (s.cores <= s.free)
                & ((now + s.duration <= shadow) | (s.cores <= extra)))
        pick = jnp.argmin(jnp.where(cand, rank, n))
        do = jnp.any(cand) & has_head
        pick_mask = (jnp.arange(n) == pick) & do
        # QueueSim decrements the reservation's spare only when the job
        # rode in on it (fits_in_extra), even if it also drains in time
        used_extra = jnp.where(do & (s.cores[pick] <= extra),
                               s.cores[pick], 0.0)
        return _start_rows(s, pick_mask, now), extra - used_extra

    s, _ = jax.lax.fori_loop(0, bf_passes, body, (s, extra))
    return s
