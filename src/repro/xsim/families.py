"""Robustness scenario families: named fault/elasticity regimes as data.

A *family* is a recipe that turns a grid label into a
``runtime.fault.FaultSchedule`` — the whole robustness axis of the
benchmark is data in the fixed-slot job table, not new engine code:

* ``clean``    — no capacity events; byte-for-byte the pre-faults grid
  (``cfg.n_faults == 0`` statically elides the fault machinery).
* ``faulty``   — a node failure mid-run: ``FAIL_FRAC`` of the machine
  dies (running jobs killed and requeued, lost core-seconds charged as
  restart overhead), recovering two hours later.
* ``elastic``  — a malleable-capacity center: graceful drain/grow
  cycles (nodes leave as their running work completes — no kills),
  exercising ASA's estimator under non-stationary queue waits.
* ``preempt``  — the same resize plan taken preemptively: shrinks kill
  the youngest running jobs immediately (spot/preemptible semantics).

Fault times are anchored after the workflow submission epoch ``t0`` and
offset per seed, so sibling seeds of one cell stress different phases
of the workflow instead of replaying one global incident.
"""

from __future__ import annotations

import dataclasses

from repro.runtime import fault
from repro.runtime.elastic import resize_schedule
from repro.xsim.grid import ScenarioGrid, XSimConfig, make_grid

FAMILIES = ("clean", "faulty", "elastic", "preempt")

# fixed fault-slot count per family (XSimConfig.n_faults)
N_FAULT_SLOTS = {"clean": 0, "faulty": 2, "elastic": 4, "preempt": 4}

FAIL_FRAC = 0.25      # faulty: fraction of the machine that dies
RESIZE_FRAC = 0.30    # elastic/preempt: first shrink/grow amplitude
RECOVER_S = 7200.0    # faulty: failed nodes rejoin after two hours


def family_schedule(family: str, label: dict,
                    t0: float) -> fault.FaultSchedule | None:
    """The family's FaultSchedule for one grid cell label (or None)."""
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; expected one of "
                         f"{FAMILIES}")
    if family == "clean":
        return None
    seed = int(label.get("seed", 0))
    if family == "faulty":
        # failure lands 30/60/90 min after the workflow submits
        t_fail = t0 + 1800.0 * (1 + seed % 3)
        return fault.FaultSchedule((
            fault.fail(t_fail, FAIL_FRAC),
            fault.grow(t_fail + RECOVER_S, FAIL_FRAC),
        ))
    # elastic / preempt: two shrink/grow cycles, phase-shifted per seed
    t_a = t0 + 1200.0 * (1 + seed % 2)
    return resize_schedule(
        [(t_a, -RESIZE_FRAC),
         (t_a + 3600.0, +RESIZE_FRAC),
         (t_a + 5400.0, -RESIZE_FRAC / 2),
         (t_a + 9000.0, +RESIZE_FRAC / 2)],
        preempt=(family == "preempt"))


def family_grid(cfg: XSimConfig, family: str = "clean",
                **make_grid_kw) -> ScenarioGrid:
    """``make_grid`` with the family's fault schedules folded in.

    Patches ``cfg.n_faults`` to the family's slot count (``clean``
    keeps 0 — the fault machinery is statically absent) and wires the
    per-label schedule recipe through ``make_grid(fault_sched=...)``.
    All other ``make_grid`` keywords pass through unchanged.
    """
    cfg = dataclasses.replace(cfg, n_faults=N_FAULT_SLOTS[family])
    if family == "clean":
        return make_grid(cfg, **make_grid_kw)
    return make_grid(
        cfg, fault_sched=lambda lab: family_schedule(family, lab, cfg.t0),
        **make_grid_kw)
