"""Strategy drivers for xsim: BigJob / Per-Stage / ASA / ASA-Naive job-table
rows, and the ASA estimator-fleet wiring (`repro.core.asa.init_batch`).

A strategy is *data* in xsim: the same event engine runs all four, they
differ only in the workflow rows written into the job table (and the
per-policy hooks in events.py). ``add_workflow`` builds those rows
host-side for a single scenario (cross-validation, tests); grid.py builds
the same rows as traced jnp for vmapped scenario construction.

ASA's wait estimates a_y are sampled from the scenario's LIVE estimator
*inside* the scan (events.py chain hook) and the estimator learns from
every observed stage wait mid-scenario — the frozen pre-draw of the first
xsim release is gone. The §4.3 cross-run persistence loop on top of that
is ``update_fleet``: between sweeps, each geometry's shared estimator
absorbs the observed first-stage waits and seeds the next sweep's
per-scenario states (``grid.run_grid`` slices the fleet per scenario).

ASA-Naive (§4.5, no dependency support) shares ASA's cascade rows but
drops the afterok edge; the events.py start hook charges idle/cancel
overhead and resubmits cancelled allocations.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import asa
from repro.core.bins import make_bins
from repro.core.losses import zero_one
from repro.sched import strategies
from repro.sched.workflows import Workflow
from repro.xsim.state import ASA, ASA_NAIVE, BIGJOB, PENDING, PILOT, add_job

# ------------------------------------------------------------ stage tables


def stage_arrays(wf: Workflow, scale: int, max_stages: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(cores, durations, valid) padded to ``max_stages`` — grid cell data."""
    s = len(wf.stages)
    if s > max_stages:
        raise ValueError(f"{wf.name} has {s} stages > max_stages={max_stages}")
    cores = np.zeros(max_stages, np.float32)
    durs = np.zeros(max_stages, np.float32)
    valid = np.zeros(max_stages, bool)
    for y, st in enumerate(wf.stages):
        cores[y] = st.cores(scale)
        durs[y] = st.duration(scale)
        valid[y] = True
    return cores, durs, valid


def add_workflow(table: dict[str, np.ndarray], offset: int, wf: Workflow,
                 scale: int, policy: int, t0: float) -> int:
    """Write one workflow's stage rows into a host-side table.

    Returns the number of rows used. ASA rows carry the afterok
    dependency edge; ASA-Naive and learned-policy (RL) rows share the
    cascade structure (``wf_next``) but not the dependency — their early
    starts are handled by the events.py naive hook. Wait estimates are
    sampled at run time from the scenario's live estimator (or, for RL,
    the policy head), so no predictions are written here.
    """
    if policy == BIGJOB:
        add_job(table, offset, cores=wf.peak_cores(scale),
                duration=wf.total_exec(scale), submit=t0, status=PENDING,
                is_wf=True)
        return 1
    if policy == PILOT:
        # one pilot allocation at peak width; the stages cycle inside it,
        # so its walltime adds the pilot bootstrap + per-stage dispatch
        # latency on top of the serialized stage work (run_pilot's model)
        add_job(table, offset, cores=wf.peak_cores(scale),
                duration=strategies.pilot_duration(wf, scale), submit=t0,
                status=PENDING, is_wf=True)
        return 1
    s = len(wf.stages)
    with_dep = policy == ASA  # naive (§4.5) + RL: no dependency support
    for y, st in enumerate(wf.stages):
        add_job(
            table, offset + y,
            cores=st.cores(scale), duration=st.duration(scale),
            submit=t0 if y == 0 else np.inf, status=PENDING,
            start_dep=offset + y - 1 if y > 0 and with_dep else -1,
            wf_next=offset + y + 1 if y + 1 < s else -1,
            is_wf=True,
        )
    return s


# ------------------------------------------------------------- ASA fleet


def init_fleet(n: int, m: int = 53, seed: int = 0) -> asa.ASAState:
    """One Algorithm-1 estimator per job geometry, as a batched state."""
    return asa.init_batch(m, n, jax.random.PRNGKey(seed))


def scenario_estimators(fleet: asa.ASAState, geo_idx: jax.Array,
                        pred_seed: int = 1) -> asa.ASAState:
    """Slice the per-geometry fleet into per-scenario live estimators.

    Every scenario gets its geometry's current state (log_p, round state)
    with an independent PRNG key (folded from the geometry key, the sweep
    seed and the scenario index), so sibling seeds of one cell draw
    independent Algorithm-1 action sequences — as independent runs against
    the shared state do in the event-driven campaign.
    """
    per = jax.tree.map(lambda x: x[geo_idx], fleet)
    n = geo_idx.shape[0]
    keys = jax.vmap(jax.random.fold_in)(
        per.key, jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(pred_seed) *
        jnp.uint32(100_003))
    return per._replace(key=keys)


def update_fleet(fleet: asa.ASAState, waits: jax.Array,
                 valid: jax.Array, gamma: float = 1.0,
                 bins: jax.Array | None = None) -> asa.ASAState:
    """Observe true waits: ``waits``/(``valid``) are (n_geometries, k);
    each geometry's estimator takes its k observations in sequence via
    ``asa.batched_step`` (the tuned §4.5 policy, as sched.strategies)."""
    m = fleet.log_p.shape[-1]
    if bins is None:
        bins = jnp.asarray(make_bins(m), jnp.float32)
    g = jnp.float32(gamma)
    for j in range(waits.shape[1]):
        w = jnp.maximum(waits[:, j], 1.0)
        lv = jax.vmap(lambda wi: zero_one(bins, wi))(w)
        stepped, _ = jax.vmap(
            lambda s, l: asa.step(s, l, g, policy="tuned"),
            in_axes=(0, 0), out_axes=(0, 0))(fleet, lv)
        keep = valid[:, j]
        fleet = jax.tree.map(
            lambda new, old: jnp.where(
                jnp.reshape(keep, (-1,) + (1,) * (new.ndim - 1)), new, old),
            stepped, fleet)
    return fleet
