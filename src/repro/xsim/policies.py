"""Strategy drivers for xsim: BigJob / Per-Stage / ASA job-table rows, and
the ASA estimator-fleet wiring (`repro.core.asa.init_batch`/`batched_step`).

A strategy is *data* in xsim: the same event engine runs all three, they
differ only in the workflow rows written into the job table (and the
per-policy hooks in events.py). ``add_workflow`` builds those rows
host-side for a single scenario (cross-validation, tests); grid.py builds
the same rows as traced jnp for vmapped scenario construction.

ASA's sampled wait estimates a_y are drawn from the fleet *before* the
sweep (frozen per scenario) — the event-driven ``strategies.run_asa``
re-samples from a state that also learns mid-run; freezing is the price
of keeping the sweep a single batched program, and is a good
approximation because within-run learning moves p by at most s ≪ warm-up
observations. Learning happens between sweeps via ``update_fleet``
(paper §4.3: Algorithm-1 state persists across runs).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import asa
from repro.core.bins import make_bins
from repro.core.losses import zero_one
from repro.sched.workflows import Workflow
from repro.xsim.state import ASA, BIGJOB, PENDING, add_job

# ------------------------------------------------------------ stage tables


def stage_arrays(wf: Workflow, scale: int, max_stages: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(cores, durations, valid) padded to ``max_stages`` — grid cell data."""
    s = len(wf.stages)
    if s > max_stages:
        raise ValueError(f"{wf.name} has {s} stages > max_stages={max_stages}")
    cores = np.zeros(max_stages, np.float32)
    durs = np.zeros(max_stages, np.float32)
    valid = np.zeros(max_stages, bool)
    for y, st in enumerate(wf.stages):
        cores[y] = st.cores(scale)
        durs[y] = st.duration(scale)
        valid[y] = True
    return cores, durs, valid


def add_workflow(table: dict[str, np.ndarray], offset: int, wf: Workflow,
                 scale: int, policy: int, t0: float,
                 preds: np.ndarray | None = None) -> int:
    """Write one workflow's stage rows into a host-side table.

    Returns the number of rows used. ``preds`` are the ASA wait estimates
    a_y (seconds), required when ``policy == ASA``.
    """
    if policy == BIGJOB:
        add_job(table, offset, cores=wf.peak_cores(scale),
                duration=wf.total_exec(scale), submit=t0, status=PENDING,
                is_wf=True)
        return 1
    s = len(wf.stages)
    if policy == ASA and (preds is None or len(preds) < s):
        raise ValueError("ASA policy needs one wait estimate per stage")
    for y, st in enumerate(wf.stages):
        add_job(
            table, offset + y,
            cores=st.cores(scale), duration=st.duration(scale),
            submit=t0 if y == 0 else np.inf, status=PENDING,
            start_dep=offset + y - 1 if y > 0 else -1,
            wf_next=offset + y + 1 if y + 1 < s else -1,
            is_wf=True,
            pred_wait=float(preds[y]) if policy == ASA else 0.0,
        )
    return s


# ------------------------------------------------------------- ASA fleet


def init_fleet(n: int, m: int = 53, seed: int = 0) -> asa.ASAState:
    """One Algorithm-1 estimator per job geometry, as a batched state."""
    return asa.init_batch(m, n, jax.random.PRNGKey(seed))


def sample_predictions(fleet: asa.ASAState, geo_idx: jax.Array,
                       key: jax.Array, n_preds: int,
                       bins: jax.Array | None = None,
                       mode: str = "greedy") -> jax.Array:
    """(n_scenarios, n_preds) wait estimates for the frozen ASA cascade.

    ``greedy`` (default) gives every stage its geometry's MAP wait. The
    event-driven runner re-samples from a state that re-sharpens at every
    stage start; with predictions frozen before the sweep, *consistency*
    across a scenario's stages is what keeps the §3.2 cascade stable —
    uniformly wrong-but-equal estimates degrade gracefully in both
    directions (under-prediction is absorbed by the afterok dependency,
    over-prediction cancels out of E_y − a_{y+1}), whereas i.i.d. draws
    from a multi-modal p can delay a successor by the full bin gap.
    ``sample`` draws Algorithm-1 line-4 actions i.i.d. instead.
    """
    if bins is None:
        bins = jnp.asarray(make_bins(fleet.log_p.shape[-1]), jnp.float32)
    log_p = fleet.log_p[geo_idx]                     # (n_scenarios, m)
    if mode == "greedy":
        acts = jnp.broadcast_to(jnp.argmax(log_p, axis=-1)[:, None],
                                (log_p.shape[0], n_preds))
    elif mode == "sample":
        keys = jax.random.split(key, log_p.shape[0])
        acts = jax.vmap(
            lambda k, lp: jax.random.categorical(k, lp, shape=(n_preds,))
        )(keys, log_p)
    else:
        raise ValueError(f"unknown prediction mode {mode!r}")
    return bins[acts]


def update_fleet(fleet: asa.ASAState, waits: jax.Array,
                 valid: jax.Array, gamma: float = 1.0,
                 bins: jax.Array | None = None) -> asa.ASAState:
    """Observe true waits: ``waits``/(``valid``) are (n_geometries, k);
    each geometry's estimator takes its k observations in sequence via
    ``asa.batched_step`` (the tuned §4.5 policy, as sched.strategies)."""
    m = fleet.log_p.shape[-1]
    if bins is None:
        bins = jnp.asarray(make_bins(m), jnp.float32)
    g = jnp.float32(gamma)
    for j in range(waits.shape[1]):
        w = jnp.maximum(waits[:, j], 1.0)
        lv = jax.vmap(lambda wi: zero_one(bins, wi))(w)
        stepped, _ = jax.vmap(
            lambda s, l: asa.step(s, l, g, policy="tuned"),
            in_axes=(0, 0), out_axes=(0, 0))(fleet, lv)
        keep = valid[:, j]
        fleet = jax.tree.map(
            lambda new, old: jnp.where(
                jnp.reshape(keep, (-1,) + (1,) * (new.ndim - 1)), new, old),
            stepped, fleet)
    return fleet
