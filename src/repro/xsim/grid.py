"""Scenario-grid construction + fleet sweep runner.

A *grid cell* is (center × scale × workflow × policy); a *scenario* is a
cell plus a PRNG seed drawing its background workload. All cell
parameters are data (stacked arrays), so ``jax.vmap(build_scenario)``
materializes thousands of scenarios in one traced program and
``events.sweep`` runs them as one batched ``lax.scan`` — the fleet-scale
substrate the ROADMAP's "as many scenarios as you can imagine" asks for.

The background generator mirrors ``QueueSim``'s calibrated model
(Poisson bursts, log-normal widths/durations, warm-start residuals +
backlog) with two slotted-state approximations, documented in README.md:
burst sizes are drawn per arrival *group* up front, and the warm-start
fill stops at the capacity target instead of clipping the last job.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.runtime.fault import FaultSchedule
from repro.sched.centers import CENTERS, CenterProfile
from repro.sched.strategies import PILOT_STARTUP_S, PILOT_TASK_LATENCY_S
from repro.sched.workflows import WORKFLOWS, Workflow
from repro.xsim import backfill, events, policies
from repro.xsim.state import (ASA_NAIVE, BIGJOB, INVALID, PENDING, PILOT,
                              POLICY_NAMES, QUEUED, RL, RL_FEATURES,
                              RUNNING, ScenarioState)


class XCenter(NamedTuple):
    """Center parameters as data (vmap-able across scenarios)."""

    total_cores: jax.Array
    bg_arrival_rate: jax.Array
    bg_cores_mean: jax.Array
    bg_cores_sigma: jax.Array
    bg_duration_mean_s: jax.Array
    bg_duration_sigma: jax.Array
    bg_backlog: jax.Array
    bg_burst_mean: jax.Array


def center_params(p: CenterProfile, shrink: float = 1.0) -> XCenter:
    """A (possibly miniaturized) center. ``shrink`` scales the machine,
    the backlog and the arrival rate together, preserving offered load —
    small grids simulate fast while keeping the congestion regime."""
    return XCenter(
        total_cores=jnp.float32(max(p.total_cores * shrink, 8.0)),
        bg_arrival_rate=jnp.float32(p.bg_arrival_rate * shrink),
        bg_cores_mean=jnp.float32(p.bg_cores_mean),
        bg_cores_sigma=jnp.float32(p.bg_cores_sigma),
        bg_duration_mean_s=jnp.float32(p.bg_duration_mean_s),
        bg_duration_sigma=jnp.float32(p.bg_duration_sigma),
        bg_backlog=jnp.float32(max(round(p.bg_initial_backlog * shrink), 1)),
        bg_burst_mean=jnp.float32(p.bg_burst_mean),
    )


@dataclass(frozen=True)
class XSimConfig:
    """Static shape/budget parameters shared by a whole grid."""

    n_warm: int = 48         # warm-start running-job slots
    n_backlog: int = 32      # queued-backlog slots
    n_arrivals: int = 64     # future background-arrival slots
    max_stages: int = 9      # Montage has 9
    t0: float = 7200.0       # workflow submission epoch (runner.WARMUP_S)
    horizon: float = 10 * 86400.0  # arrivals beyond this are dropped
    warm_fill: float = 0.97  # warm-start capacity target (QueueSim's 97%)
    pred_mode: str = "greedy"  # cascade a_y: live MAP ("greedy") or the
    #   Algorithm-1 line-4 draw ("sample"). Fleet sweeps default to the
    #   consistent MAP — the estimator still learns (and the MAP moves)
    #   within the run; i.i.d. draws from a still-multi-modal p can delay
    #   a successor by the full bin gap. "sample" matches the event-driven
    #   tuned runner call-for-call (cross-validation uses state.freeze).
    chunk_steps: int = 8     # scan-chunk size between drain-exit checks
    #   (events.simulate): smaller = finer early exit, larger = fewer
    #   while_loop round-trips; 0 disables chunking (one static scan).
    #   Bit-identical results for every value — drained steps are no-ops.
    trace_capacity: int = 0  # event-ring slots per scenario
    #   (repro.obs.trace); 0 = untraced, statically — no trace ops are
    #   ever staged and the sweep is the pre-observability program.
    n_faults: int = 0        # capacity-fault slots per scenario
    #   (runtime.fault.FaultSchedule events); 0 = no fault machinery is
    #   ever staged and the sweep is the pre-faults program, bit for bit.

    def __post_init__(self) -> None:
        if self.pred_mode not in ("greedy", "sample"):
            raise ValueError(f"unknown pred_mode {self.pred_mode!r}")
        if self.chunk_steps < 0:
            raise ValueError(f"chunk_steps must be >= 0, got "
                             f"{self.chunk_steps}")
        if self.trace_capacity < 0:
            raise ValueError(f"trace_capacity must be >= 0, got "
                             f"{self.trace_capacity}")
        if self.n_faults < 0:
            raise ValueError(f"n_faults must be >= 0, got {self.n_faults}")

    @property
    def max_jobs(self) -> int:
        return self.n_warm + self.n_backlog + self.n_arrivals + self.max_stages

    def with_trace(self, capacity: int | None = None) -> "XSimConfig":
        """This config with event tracing on. The default capacity —
        4·max_jobs — covers the worst event sequence a scenario can emit
        (submit + start + finish per job, plus the naive cancel/resubmit
        detours) with slack, so rings normally never overflow."""
        import dataclasses

        if capacity is None:
            capacity = 4 * self.max_jobs
        elif capacity < 1:
            # an explicit "trace with no room" is a contradiction, not a
            # request to disable tracing (that is the default config)
            raise ValueError(f"with_trace needs trace_capacity >= 1, "
                             f"got {capacity}")
        return dataclasses.replace(self, trace_capacity=capacity)

    @property
    def n_steps(self) -> int:
        """Safe event budget: each job costs at most one admission step
        and one completion step (same-instant admissions batch, and the
        in-step hook drain absorbs whole stage cascades into their
        admission step), plus the naive cancel/resubmit detours — every
        stage can cancel at most once, and a cancel adds one repass step
        plus one same-instant resubmission-admission step, hence the
        ``2·max_stages`` slack (+16 base cushion). The old
        ``6·max_stages`` same-instant-cascade term is gone — that is the
        step-budget half of the event-bound optimization — and the
        chunked drain exit makes any remaining overcount nearly free
        (drained scenarios stop stepping, so only truly long scenarios
        ever touch the budget tail). Each capacity fault costs one event
        step of its own plus, in the worst FAIL case, one extra
        completion-and-restart step per killed-and-requeued job — hence
        the ``n_faults · (1 + max_jobs)`` term."""
        return (2 * self.max_jobs + 2 * self.max_stages + 16
                + self.n_faults * (1 + self.max_jobs))


def build_scenario(key: jax.Array, center: XCenter, wf_cores: jax.Array,
                   wf_durs: jax.Array, wf_valid: jax.Array,
                   est, policy: jax.Array, fault_t: jax.Array,
                   fault_c: jax.Array, fault_k: jax.Array,
                   cfg: XSimConfig) -> ScenarioState:
    """One scenario as a pure function of (key, cell data). vmap freely.

    ``est`` is the scenario's live Algorithm-1 estimator (its geometry's
    fleet slice, see ``policies.scenario_estimators``) — predictions are
    sampled from it, and it learns, inside the event scan.
    ``fault_t``/``fault_c``/``fault_k`` are the scenario's capacity-fault
    schedule as (cfg.n_faults,) arrays (``FaultSchedule.as_arrays``)."""
    k_warm_c, k_warm_d, k_warm_u, k_back_c, k_back_d, k_arr_g, k_arr_b, \
        k_arr_c, k_arr_d = jax.random.split(key, 9)
    total = center.total_cores

    def widths(k, n):
        w = jnp.exp(center.bg_cores_mean
                    + center.bg_cores_sigma * jax.random.normal(k, (n,)))
        return jnp.clip(jnp.round(w), 1.0, jnp.maximum(total // 2, 1.0))

    def durations(k, n):
        d = jnp.exp(center.bg_duration_mean_s
                    + center.bg_duration_sigma * jax.random.normal(k, (n,)))
        return jnp.clip(d, 30.0, 7.0 * 86400.0)

    # --- warm start: machine filled to ~warm_fill with residual jobs ----
    wc = widths(k_warm_c, cfg.n_warm)
    wd = durations(k_warm_d, cfg.n_warm)
    w_ok = jnp.cumsum(wc) <= cfg.warm_fill * total
    wc = jnp.where(w_ok, wc, 0.0)
    w_end = jax.random.uniform(k_warm_u, (cfg.n_warm,), minval=0.05,
                               maxval=1.0) * wd
    free = total - jnp.sum(wc)

    # --- backlog: queued at t=0, FCFS position = row order --------------
    bc = widths(k_back_c, cfg.n_backlog)
    bd = durations(k_back_d, cfg.n_backlog)
    b_ok = jnp.arange(cfg.n_backlog) < center.bg_backlog

    # --- future arrivals: Poisson bursts --------------------------------
    gaps = jax.random.exponential(k_arr_g, (cfg.n_arrivals,)) \
        / center.bg_arrival_rate
    group_t = jnp.cumsum(gaps)
    u = jax.random.uniform(k_arr_b, (cfg.n_arrivals,), minval=1e-6,
                           maxval=1.0 - 1e-6)
    p_burst = 1.0 / jnp.maximum(center.bg_burst_mean, 1.0)
    burst = jnp.where(
        center.bg_burst_mean <= 1.0, 1.0,
        jnp.floor(jnp.log(u) / jnp.log1p(-p_burst)) + 1.0)
    group_of = jnp.searchsorted(jnp.cumsum(burst),
                                jnp.arange(cfg.n_arrivals), side="right")
    a_submit = group_t[jnp.clip(group_of, 0, cfg.n_arrivals - 1)]
    ac = widths(k_arr_c, cfg.n_arrivals)
    ad = durations(k_arr_d, cfg.n_arrivals)
    a_ok = a_submit <= cfg.horizon

    # --- workflow rows (policy is data: all variants, selected) ---------
    wf_off = cfg.n_warm + cfg.n_backlog + cfg.n_arrivals
    y = jnp.arange(cfg.max_stages)
    peak = jnp.max(wf_cores)
    total_dur = jnp.sum(jnp.where(wf_valid, wf_durs, 0.0))
    n_stages = jnp.sum(wf_valid.astype(jnp.float32))
    useful_cs = jnp.sum(jnp.where(wf_valid, wf_cores * wf_durs, 0.0))
    is_big = policy == BIGJOB
    is_pilot = policy == PILOT
    # BigJob and the pilot both submit ONE peak-cores monolith; the pilot
    # additionally pays its bootstrap + per-stage internal dispatch
    # latency on the walltime (strategies.pilot_duration, mirrored here)
    single = is_big | is_pilot
    pilot_dur = total_dur + PILOT_STARTUP_S + n_stages * PILOT_TASK_LATENCY_S
    single_dur = jnp.where(is_pilot, pilot_dur, total_dur)
    # ASA-Naive + the learned policy: cascade rows, no afterok edge
    no_dep = (policy == ASA_NAIVE) | (policy == RL)
    f_valid = jnp.where(single, y == 0, wf_valid)
    f_cores = jnp.where(single, jnp.where(y == 0, peak, 0.0), wf_cores)
    f_durs = jnp.where(single, jnp.where(y == 0, single_dur, 0.0), wf_durs)
    f_submit = jnp.where(y == 0, cfg.t0, jnp.inf)
    nxt_valid = jnp.concatenate([f_valid[1:], jnp.zeros(1, bool)])
    f_next = jnp.where(f_valid & nxt_valid & ~single, wf_off + y + 1, -1)
    f_dep = jnp.where(f_valid & (y > 0) & ~single & ~no_dep,
                      wf_off + y - 1, -1)
    f_rows = jnp.where(f_valid, wf_off + y, -1)
    waste_cs = jnp.where(is_pilot, peak * pilot_dur - useful_cs, 0.0)

    # --- assemble the table ---------------------------------------------
    def cat(warm, back, arr, wf):
        return jnp.concatenate([warm, back, arr, wf])

    zeros = jnp.zeros
    nwm, nbk, nar, nst = cfg.n_warm, cfg.n_backlog, cfg.n_arrivals, \
        cfg.max_stages
    inf = jnp.inf
    submit = cat(zeros(nwm), zeros(nbk), jnp.where(a_ok, a_submit, inf),
                 f_submit)
    cores = cat(wc, jnp.where(b_ok, bc, 0.0), jnp.where(a_ok, ac, 0.0),
                f_cores)
    duration = cat(wd, bd, ad, f_durs)
    start = cat(jnp.where(w_ok, 0.0, inf), jnp.full(nbk, inf),
                jnp.full(nar, inf), jnp.full(nst, inf))
    end = cat(jnp.where(w_ok, w_end, inf), jnp.full(nbk, inf),
              jnp.full(nar, inf), jnp.full(nst, inf))
    status = cat(jnp.where(w_ok, RUNNING, INVALID),
                 jnp.where(b_ok, QUEUED, INVALID),
                 jnp.where(a_ok, PENDING, INVALID),
                 jnp.where(f_valid, PENDING, INVALID)).astype(jnp.int32)
    start_dep = cat(jnp.full(nwm, -1), jnp.full(nbk, -1), jnp.full(nar, -1),
                    f_dep).astype(jnp.int32)
    wf_next = cat(jnp.full(nwm, -1), jnp.full(nbk, -1), jnp.full(nar, -1),
                  f_next).astype(jnp.int32)
    is_wf = cat(zeros(nwm, bool), zeros(nbk, bool), zeros(nar, bool),
                f_valid)

    return ScenarioState(
        submit=submit, cores=cores, duration=duration, start=start, end=end,
        status=status, start_dep=start_dep, wf_next=wf_next, is_wf=is_wf,
        pred_wait=zeros(cfg.max_jobs),
        expected_end=jnp.full(cfg.max_jobs, -jnp.inf),
        wf_rows=f_rows.astype(jnp.int32),
        hold=zeros(cfg.max_stages),
        canc_start=jnp.full(cfg.max_stages, jnp.inf),
        start_pending=zeros(cfg.max_stages, bool),
        chain_pending=zeros(cfg.max_stages, bool),
        rl_obs=zeros((cfg.max_stages, RL_FEATURES)),
        rl_act=jnp.full(cfg.max_stages, -1, jnp.int32),
        est=est,
        t=jnp.float32(0.0), free=free, total=total,
        policy=policy.astype(jnp.int32), t0=jnp.float32(cfg.t0),
        busy_cs=jnp.float32(0.0), min_free=free,
        oh_cs=jnp.float32(0.0), misses=jnp.int32(0),
        repass=jnp.asarray(False),
        pred_greedy=jnp.asarray(cfg.pred_mode == "greedy"),
        steps=jnp.int32(0),
        fault_t=fault_t.astype(jnp.float32),
        fault_c=fault_c.astype(jnp.float32),
        fault_k=fault_k.astype(jnp.int32),
        fault_next=jnp.int32(0),
        cap_debt=jnp.float32(0.0),
        restarts=jnp.int32(0),
        restart_cs=jnp.float32(0.0),
        pilot_waste_cs=waste_cs.astype(jnp.float32),
        trace=(obs_trace.init(cfg.trace_capacity)
               if cfg.trace_capacity else None),
    )


build_batch = jax.jit(
    jax.vmap(build_scenario, in_axes=(0,) * 10 + (None,)),
    static_argnums=(10,))


@dataclass
class ScenarioGrid:
    """A flat batch of scenarios + the cell labels that produced them."""

    cfg: XSimConfig
    keys: jax.Array               # (B, 2) PRNG keys
    centers: XCenter              # stacked (B,)
    wf_cores: jax.Array           # (B, S)
    wf_durs: jax.Array            # (B, S)
    wf_valid: jax.Array           # (B, S)
    policies: jax.Array           # (B,)
    fault_t: jax.Array            # (B, n_faults) fault times, +inf pad
    fault_c: jax.Array            # (B, n_faults) core deltas (>= 0)
    fault_k: jax.Array            # (B, n_faults) FAULT_* kinds
    geo_idx: np.ndarray           # (B,) geometry id (center, scale) per row
    labels: list[dict]            # per-scenario {center, scale, workflow, ...}

    @property
    def n(self) -> int:
        return int(self.policies.shape[0])

    @property
    def has_faults(self) -> bool:
        """Static: any fault slots at all (cfg.n_faults > 0). Statically
        False elides the whole fault path from the swept program."""
        return int(self.fault_t.shape[1]) > 0

    def build(self, ests) -> ScenarioState:
        """``ests`` is a (B,)-batched ASAState (per-scenario estimators)."""
        return build_batch(self.keys, self.centers, self.wf_cores,
                           self.wf_durs, self.wf_valid, ests,
                           self.policies, self.fault_t, self.fault_c,
                           self.fault_k, self.cfg)


def make_grid(cfg: XSimConfig,
              center_names: Sequence[str] = ("hpc2n", "uppmax"),
              workflows: Sequence[str | Workflow] =
              ("montage", "blast", "statistics"),
              policy_ids: Sequence[int] = (0, 1, 2),
              n_seeds: int = 4, shrink: float = 1.0 / 64.0,
              scales: Sequence[int] | None = None,
              seed: int = 0, fault_sched=None) -> ScenarioGrid:
    """The full scenario product, flattened to one batch.

    Cells = centers × their paper scales × workflows × policies × seeds.
    ``shrink`` miniaturizes the centers (default 1/64: HPC2n → 263 cores)
    so the slotted tables stay small; workflow scales shrink alongside.
    ``workflows`` entries are names in ``WORKFLOWS`` or ``Workflow``
    instances (custom stage profiles, e.g. single-stage probes).

    ``fault_sched`` injects capacity faults (``cfg.n_faults`` must cover
    the longest schedule): a ``runtime.fault.FaultSchedule`` applied to
    every scenario, or a callable ``label_dict -> FaultSchedule`` for
    per-scenario schedules (see ``repro.xsim.families`` for the standard
    robustness families). Event ``frac`` values are fractions of the
    center's *original* (shrunk) total cores, converted to whole cores
    host-side here.
    """
    cells, labels, geo, bg_keys, faults = [], [], [], [], []
    if fault_sched is not None and cfg.n_faults == 0:
        raise ValueError("fault_sched given but cfg.n_faults == 0; set "
                         "XSimConfig(n_faults=...) to size the fault slots")
    base = jax.random.PRNGKey(seed)
    geo_ids: dict[tuple[str, int], int] = {}
    for cname in center_names:
        profile = CENTERS[cname]
        total_cores = float(max(profile.total_cores * shrink, 8.0))
        for scale in (scales or profile.scales):
            eff_scale = max(int(round(scale * shrink)), 2)
            gid = geo_ids.setdefault((cname, scale), len(geo_ids))
            for w in workflows:
                wf = w if isinstance(w, Workflow) else WORKFLOWS[w]
                sc, sd, sv = policies.stage_arrays(
                    wf, eff_scale, cfg.max_stages)
                for pol in policy_ids:
                    for s in range(n_seeds):
                        cells.append((profile, sc, sd, sv, pol))
                        geo.append(gid)
                        # background depends ONLY on (geometry, seed):
                        # strategies and workflows of one cell see the
                        # identical machine, as run_table1 does
                        bg_keys.append(jax.random.fold_in(
                            base, gid * 100_003 + s))
                        lab = dict(center=cname, scale=scale,
                                   workflow=wf.name,
                                   strategy=POLICY_NAMES[pol],
                                   seed=s)
                        labels.append(lab)
                        sched = (fault_sched(lab) if callable(fault_sched)
                                 else fault_sched) or FaultSchedule()
                        faults.append(sched.as_arrays(cfg.n_faults,
                                                      total_cores))
    B = len(cells)
    if B == 0:
        raise ValueError(
            "empty scenario grid: the centers × scales × workflows × "
            "policies × seeds product has no cells "
            f"(centers={list(center_names)!r}, workflows={list(workflows)!r},"
            f" policy_ids={list(policy_ids)!r}, n_seeds={n_seeds})")
    stacked_centers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[center_params(c[0], shrink) for c in cells])
    return ScenarioGrid(
        cfg=cfg,
        keys=jnp.stack(bg_keys),
        centers=stacked_centers,
        wf_cores=jnp.stack([jnp.asarray(c[1]) for c in cells]),
        wf_durs=jnp.stack([jnp.asarray(c[2]) for c in cells]),
        wf_valid=jnp.stack([jnp.asarray(c[3]) for c in cells]),
        policies=jnp.asarray([c[4] for c in cells], jnp.int32),
        fault_t=jnp.stack([jnp.asarray(f[0]) for f in faults]),
        fault_c=jnp.stack([jnp.asarray(f[1]) for f in faults]),
        fault_k=jnp.stack([jnp.asarray(f[2]) for f in faults]),
        geo_idx=np.asarray(geo),
        labels=labels,
    )


def run_grid(grid: ScenarioGrid, fleet=None, *, pred_seed: int = 1,
             bf_passes: int = backfill.BF_PASSES,
             freed_mode: str = "ref", params=None,
             rl_mode: str = "sample", n_shards: int | None = None,
             mesh=None):
    """Build + sweep the whole grid in one jitted batched program.

    ``fleet`` is a batched ASAState (one estimator per geometry); when
    None a fresh fleet is initialised (cold estimators). Every scenario
    carries its geometry's live estimator slice through the scan —
    predictions are sampled, and learning happens, *within* the run;
    ``pred_seed`` decorrelates the per-scenario PRNG streams across
    sweeps. ``freed_mode`` selects the reservation-scan backend
    (``"tpu"`` = Pallas kernel). ``params`` is the learned submission
    policy's weight pytree — required when the grid contains policy id 4
    scenarios; ``rl_mode`` picks sampled (training) vs greedy
    (evaluation) actions for them.

    ``n_shards`` / ``mesh`` select the device-parallel path: the scenario
    axis is shard_mapped over a 1-D ``scenarios`` mesh (``mesh`` wins
    when both are given; ``n_shards`` builds one over the first N visible
    devices via ``launch.mesh.make_scenarios_mesh``, validating N against
    the device inventory). Batches not divisible by the shard count are
    padded and the pad rows dropped; the result is bit-identical to the
    default single-device vmap (both pinned by test). Returns
    (final_states, metrics dict of (B,) arrays).
    """
    from repro.xsim import compare

    pols = np.asarray(grid.policies)
    if params is None and bool(np.any(pols == RL)):
        raise ValueError(
            "grid contains learned-policy (rl, id 4) scenarios; pass "
            "params= (repro.rl.policy.PolicyParams) to run_grid")
    if rl_mode not in ("sample", "greedy"):
        raise ValueError(f"unknown rl_mode {rl_mode!r}")
    if mesh is None and n_shards is not None:
        from repro.launch.mesh import make_scenarios_mesh
        mesh = make_scenarios_mesh(n_shards)
    if fleet is None:
        fleet = policies.init_fleet(int(grid.geo_idx.max()) + 1)
    ests = policies.scenario_estimators(
        fleet, jnp.asarray(grid.geo_idx), pred_seed)
    states = grid.build(ests)
    # RL shares ASA-Naive's no-dependency world (cancel/resubmit machinery)
    has_naive = bool(np.any((pols == ASA_NAIVE) | (pols == RL)))
    kw = dict(n_steps=grid.cfg.n_steps, chunk_steps=grid.cfg.chunk_steps,
              bf_passes=bf_passes, freed_mode=freed_mode,
              pred_mode=grid.cfg.pred_mode, naive=has_naive, params=params,
              rl_mode=rl_mode, faults=grid.has_faults)
    if mesh is None:
        final = events.sweep(states, **kw)
    else:
        final = events.sharded_sweep(states, mesh=mesh, **kw)
    # metrics always run on the gathered final states: the sweep itself
    # is bit-identical across shard counts, so this keeps the metrics
    # bit-identical too (compare.sharded_batched_metrics reduces on the
    # shards instead, at the price of ~1-ULP reduction-order wiggle)
    return final, compare.batched_metrics(final)


def stage_waits(final: ScenarioState, cfg: XSimConfig
                ) -> tuple[np.ndarray, np.ndarray]:
    """(waits, valid) of shape (B, max_stages) from a batched final state."""
    sl = slice(cfg.max_jobs - cfg.max_stages, cfg.max_jobs)
    waits = np.asarray(final.start[:, sl] - final.submit[:, sl])
    valid = np.asarray(final.is_wf[:, sl]) & np.isfinite(waits)
    return waits, valid


def warm_fleet(fleet, grid: ScenarioGrid, rounds: int = 2, k: int = 8,
               seed: int = 100, params=None, n_shards: int | None = None,
               mesh=None):
    """§4.3 cross-run persistence: sweep, observe first-stage waits (a
    clean per-geometry queue sample), update every geometry's estimator,
    repeat. Returns the warmed fleet. ``params`` is forwarded to
    ``run_grid`` (required only when the grid contains learned-policy
    scenarios); ``n_shards``/``mesh`` likewise select its device-parallel
    sweep path."""
    n_geo = fleet.log_p.shape[0]
    # BigJob's and the pilot's row 0 is the peak-cores monolith, not a
    # stage-shaped job — exclude them so each geometry learns from clean
    # stage-0 samples
    stagelike = np.array([lab["strategy"] not in ("bigjob", "pilot")
                          for lab in grid.labels])
    if mesh is None and n_shards is not None:
        from repro.launch.mesh import make_scenarios_mesh
        mesh = make_scenarios_mesh(n_shards)
    for r in range(rounds):
        final, _ = run_grid(grid, fleet, pred_seed=seed + r, params=params,
                            mesh=mesh)
        waits, valid = stage_waits(final, grid.cfg)
        W = np.zeros((n_geo, k), np.float32)
        V = np.zeros((n_geo, k), bool)
        for g in range(n_geo):
            sel = (grid.geo_idx == g) & stagelike
            w = waits[sel, 0]
            w = w[valid[sel, 0]][:k]
            W[g, :len(w)] = w
            V[g, :len(w)] = True
        fleet = policies.update_fleet(fleet, jnp.asarray(W), jnp.asarray(V))
    return fleet
