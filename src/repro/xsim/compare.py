"""Metrics extraction + QueueSim cross-validation bridge.

``metrics`` reduces a finished ScenarioState to the same quantities
``sched.runner``'s RunMetrics carries (twt_s, makespan_s, core_hours,
oh_hours, utilization) so ``benchmarks/`` can consume either engine.
``scenario_from_queue_sim`` snapshots a live event-driven QueueSim into an
xsim job table — the cross-validation tests run both engines from the
*identical* machine state and compare the numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.xsim.state import (ASA, ASA_NAIVE, DONE, PILOT, QUEUED, RL,
                              RUNNING, ScenarioState, empty_table)


def metrics(s: ScenarioState) -> dict[str, jax.Array]:
    """Per-scenario scalars (vmap over a batched state for fleet metrics).

    twt_s is policy-aware: BigJob = the single job's wait, Per-Stage =
    Σ stage waits, ASA / ASA-Naive / the learned policy = *perceived*
    waits along the stage chain (stage 0's full wait, then the part of
    each stage's wait not hidden behind its predecessor's logical end,
    which includes any naive idle hold) — matching
    ``sched.strategies.run_asa``'s settled-timeline bookkeeping exactly.
    Pilot (policy 5) counts like BigJob (single job wait / wf end).

    oh_hours carries the naive/RL over-allocation, plus — for the pilot
    policy — the pilot's packing waste (charged once the pilot actually
    starts, mirroring ``run_pilot``), plus the core-seconds lost to
    fault kills (work the killed attempts consumed before restarting).
    The pilot's waste is already *inside* its single row's
    cores × duration, so its core_hours does NOT re-add oh_hours —
    preserving the CH(pilot) == CH(asa) + OH(pilot) identity that
    ``run_pilot`` satisfies on the event engine.
    """
    n = s.status.shape[0]
    wf = s.is_wf
    wait = jnp.where(wf, s.start - s.submit, 0.0)
    wait_sum = jnp.sum(jnp.where(wf, wait, 0.0))

    # ASA/naive perceived waits + logical makespan: walk the stage chain,
    # carrying the logical end  le_y = max(start_y + hold_y, le_{y−1}) + t_y
    # (run_asa's settled timeline; hold is 0 everywhere but naive misses).
    rows = jnp.clip(s.wf_rows, 0, n - 1)

    def chain(y, carry):
        le, twt = carry
        row = rows[y]
        ok = (s.wf_rows[y] >= 0) & jnp.isfinite(s.start[row])
        start_l = s.start[row] + s.hold[y]
        # a naive stage can start while an earlier stage never did (no
        # afterok edge + exhausted step budget): its predecessor logical
        # end is still -inf — count no perceived wait rather than +inf
        pwt = jnp.where(y == 0, s.start[row] - s.submit[row],
                        jnp.where(jnp.isneginf(le), 0.0,
                                  jnp.maximum(s.start[row] - le, 0.0)))
        new_le = jnp.where(y == 0, start_l,
                           jnp.maximum(start_l, le)) + s.duration[row]
        return (jnp.where(ok, new_le, le), twt + jnp.where(ok, pwt, 0.0))

    le, chain_twt = jax.lax.fori_loop(
        0, s.wf_rows.shape[0], chain,
        (jnp.float32(-jnp.inf), jnp.float32(0.0)))

    asa_like = ((s.policy == ASA) | (s.policy == ASA_NAIVE)
                | (s.policy == RL))
    twt = jnp.where(asa_like, chain_twt, wait_sum)

    wf_end = jnp.max(jnp.where(wf, s.end, -jnp.inf))
    makespan = jnp.where(asa_like, le, wf_end) - s.t0
    core_seconds = jnp.sum(jnp.where(wf, s.cores * s.duration, 0.0))
    restart_hours = s.restart_cs / 3600.0
    is_pilot = s.policy == PILOT
    started_any = jnp.any(wf & jnp.isfinite(s.start))
    pilot_oh = jnp.where(started_any, s.pilot_waste_cs, 0.0) / 3600.0
    oh_hours = jnp.where(is_pilot, pilot_oh,
                         s.oh_cs / 3600.0) + restart_hours
    core_hours = core_seconds / 3600.0 + jnp.where(is_pilot, restart_hours,
                                                   oh_hours)
    done = jnp.sum((wf & (s.status == DONE)).astype(jnp.int32))
    total_wf = jnp.sum(wf.astype(jnp.int32))
    util = s.busy_cs / jnp.maximum(s.total * s.t, 1e-9)
    return {
        "twt_s": twt,
        "makespan_s": makespan,
        "core_hours": core_hours,
        "oh_hours": oh_hours,
        "misses": s.misses,
        "utilization": util,
        "wf_done": done,
        "wf_total": total_wf,
        "restarts": s.restarts,
        "restart_hours": restart_hours,
        "policy": s.policy,
    }


batched_metrics = jax.jit(jax.vmap(metrics))


def sharded_batched_metrics(final: ScenarioState, mesh
                            ) -> dict[str, jax.Array]:
    """``batched_metrics`` under a 1-D ``scenarios`` mesh: each device
    reduces its own block of final states to the per-scenario metric
    scalars, and only the small (B,) columns are gathered — for fleets
    whose final states live sharded across devices (same padding
    semantics as ``events.sharded_sweep``). Values match the vmap path
    up to reduction order (~1 ULP on the summed columns: XLA associates
    the per-scenario sums differently per block shape), which is why
    ``run_grid`` — whose contract is bitwise device-count independence —
    computes metrics on the gathered states instead."""
    from jax.experimental.shard_map import shard_map

    from repro.parallel import fleet as pfleet

    n_shards = mesh.shape[pfleet.SCENARIO_AXIS]
    b = pfleet.batch_size(final)
    padded, _mask = pfleet.pad_batch(final, n_shards)
    spec = pfleet.shard_spec()
    fn = shard_map(jax.vmap(metrics), mesh=mesh, in_specs=(spec,),
                   out_specs=spec, check_rep=False)
    return pfleet.unpad(fn(padded), b)


def wf_rows(s: ScenarioState) -> dict[str, np.ndarray]:
    """Host-side view of the workflow rows (stage-ordered), for tests."""
    mask = np.asarray(s.is_wf)
    out = {}
    for name in ("submit", "start", "end", "cores", "duration", "status"):
        out[name] = np.asarray(getattr(s, name))[mask]
    return out


def scenario_from_queue_sim(sim, max_jobs: int) -> tuple[dict, int]:
    """Snapshot a live QueueSim into a host-side xsim job table.

    Returns (table, next_free_row). Running jobs keep their residual end
    times; queued jobs keep their submit times and FCFS positions (row
    order = queue order, and xsim's stable sort preserves it for equal
    submit times). Workflow rows are appended by the caller via
    ``policies.add_workflow`` starting at next_free_row.
    """
    table = empty_table(max_jobs)
    row = 0
    for _, jid in sorted(sim.running):
        j = sim.jobs[jid]
        if jid in sim.finished or j.canceled:
            continue
        table["submit"][row] = j.submit_time
        table["cores"][row] = j.cores
        table["duration"][row] = j.duration
        table["start"][row] = j.start_time
        table["end"][row] = j.end_time
        table["status"][row] = RUNNING
        row += 1
    for jid in sim.queue:
        j = sim.jobs[jid]
        table["submit"][row] = j.submit_time
        table["cores"][row] = j.cores
        table["duration"][row] = j.duration
        table["status"][row] = QUEUED
        row += 1
    return table, row


def queue_sim_free_cores(sim) -> float:
    return float(sim.free_cores)
