"""repro.xsim — vectorized fleet-scale scenario engine for batched ASA
evaluation.

A second, array-native simulation stack beside the event-driven
``repro.sched.queue_sim``: fixed-slot job tables, ``lax.scan`` event
stepping, ``jax.vmap`` over thousands of scenarios (``shard_map``'d
across devices via ``run_grid(n_shards=...)``), a Pallas kernel for the
EASY-backfill reservation scan. See README.md in this package for the
design and its approximations.
"""

from repro.xsim.state import (ASA, ASA_NAIVE, BIGJOB, CANCELLED, PER_STAGE,
                              POLICY_NAMES, RL, ScenarioState)
from repro.xsim.events import sharded_sweep, simulate, sweep
from repro.xsim.grid import (ScenarioGrid, XSimConfig, center_params,
                             make_grid, run_grid)
from repro.xsim.compare import batched_metrics, metrics

__all__ = [
    "ASA", "ASA_NAIVE", "BIGJOB", "CANCELLED", "PER_STAGE", "POLICY_NAMES",
    "RL", "ScenarioState", "simulate", "sweep", "sharded_sweep",
    "ScenarioGrid", "XSimConfig", "center_params", "make_grid", "run_grid",
    "batched_metrics", "metrics",
]
