"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick and for smoke tests that
must see exactly one device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ``model`` is tensor/expert-parallel; ``data`` is data+FSDP;
    ``pod`` extends the data/FSDP dimension across pods (DCN-connected).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host offers (smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_scenarios_mesh(n_shards: int | None = None) -> Mesh:
    """1-D ``scenarios`` mesh for device-parallel xsim fleet sweeps.

    ``n_shards=None`` takes every visible device. Validates the shard
    count against the actual device inventory up front, so a bad
    ``--shards`` fails with a clear message rather than deep inside a
    shard_mapped sweep. (CI fakes an 8-device CPU host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the same
    trick ``launch.dryrun`` uses for 512.)
    """
    devices = jax.devices()
    n = len(devices) if n_shards is None else n_shards
    err = shards_arg_error(n, flag="n_shards")
    if err is not None:
        raise ValueError(err)
    return Mesh(np.asarray(devices[:n]), ("scenarios",))


def shards_arg_error(n_shards: int, flag: str = "--shards") -> str | None:
    """The single source of truth for shard-count validation: None when
    ``n_shards`` fits the visible device inventory, else the error
    message. The benchmark CLIs feed it to ``parser.error`` up front (the
    PR-3 ``--engine``/``--policy`` style) and ``make_scenarios_mesh``
    raises it, so a bad count never reaches a shard_mapped sweep."""
    n_dev = len(jax.devices())
    if 1 <= n_shards <= n_dev:
        return None
    return (f"{flag} {n_shards} outside the visible device inventory "
            f"(1..{n_dev}, backend={jax.default_backend()}); set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            "importing jax to fake N CPU devices")
