"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick and for smoke tests that
must see exactly one device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ``model`` is tensor/expert-parallel; ``data`` is data+FSDP;
    ``pod`` extends the data/FSDP dimension across pods (DCN-connected).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host offers (smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
