"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns (args, build_in_shardings) where args is
the tuple of ShapeDtypeStructs fed to ``jit(...).lower`` AFTER params and
optimizer state — no device allocation anywhere (the shannon/kernels
pattern: weak-type-correct, shardable stand-ins).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.parallel.sharding import ShardingRules

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        batch["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), dt)
    return batch


def batch_shardings(batch, rules: ShardingRules, mesh) -> dict:
    out = {}
    for k, v in batch.items():
        out[k] = NamedSharding(mesh, rules.batch_spec(v.shape[0], v.ndim))
    return out


def prefill_args(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    args = [_sds((B, S), I32)]
    if cfg.family == "audio":
        args.append(_sds((B, cfg.encoder.n_frames, cfg.d_model), dt))
    if cfg.family == "vlm":
        args.append(_sds((B, cfg.encoder.n_frames, cfg.d_model), dt))
    return tuple(args)


def decode_args(cfg: ModelConfig, shape: ShapeSpec):
    """(token, caches/state[, index]) stand-ins for one decode step with a
    seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    token = _sds((B, 1), I32)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        kv = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd)
        caches = {"k": _sds(kv, dt), "v": _sds(kv, dt)}
        return (token, caches, _sds((), I32))
    if fam == "audio":
        kv = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.hd)
        xk = (cfg.n_layers, B, cfg.encoder.n_frames, cfg.n_kv_heads, cfg.hd)
        caches = {"k": _sds(kv, dt), "v": _sds(kv, dt),
                  "xk": _sds(xk, dt), "xv": _sds(xk, dt)}
        return (token, caches, _sds((), I32))
    if fam == "ssm":
        from repro.models.rwkv6 import n_heads
        H, K = n_heads(cfg), cfg.rwkv.head_dim
        state = {
            "tm_shift": _sds((cfg.n_layers, B, 1, cfg.d_model), dt),
            "cm_shift": _sds((cfg.n_layers, B, 1, cfg.d_model), dt),
            "wkv": _sds((cfg.n_layers, B, H, K, K), jnp.float32),
        }
        return (token, state)
    if fam == "hybrid":
        from repro.models.zamba2 import dims
        d_inner, H, Pd, N = dims(cfg)
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        cache_len = min(cfg.sliding_window or S, S)
        state = {
            "conv": _sds((cfg.n_layers, B, cfg.ssm.conv_width - 1, d_inner), dt),
            "ssm": _sds((cfg.n_layers, B, H, N, Pd), jnp.float32),
            "attn_k": _sds((max(n_attn, 1), B, cache_len,
                            cfg.n_kv_heads, cfg.hd), dt),
            "attn_v": _sds((max(n_attn, 1), B, cache_len,
                            cfg.n_kv_heads, cfg.hd), dt),
        }
        return (token, state, _sds((), I32))
    raise ValueError(fam)


def decode_shardings(cfg: ModelConfig, shape: ShapeSpec,
                     rules: ShardingRules, mesh, *,
                     kv_seq_shard: bool = False):
    B = shape.global_batch
    fam = cfg.family
    tok = NamedSharding(mesh, rules.batch_spec(B, 2))
    if kv_seq_shard and fam in ("dense", "moe", "vlm", "audio"):
        # flash-decoding-style: shard the cache SEQUENCE over the model
        # axis (softmax partials combined by SPMD-inserted all-reduces) —
        # the right layout when kv_heads < model-axis size.
        b_ax = rules.fsdp if (rules.fsdp and B % rules.n_fsdp == 0) else None
        kv_spec = NamedSharding(mesh, P(None, b_ax, "model", None, None))
    else:
        kv_spec = NamedSharding(
            mesh, rules.kv_cache_spec(B, cfg.n_kv_heads, stacked=True))
    if fam in ("dense", "moe", "vlm"):
        return (tok, {"k": kv_spec, "v": kv_spec},
                NamedSharding(mesh, P()))
    if fam == "audio":
        return (tok, {k: kv_spec for k in ("k", "v", "xk", "xv")},
                NamedSharding(mesh, P()))
    if fam == "ssm":
        from repro.models.rwkv6 import n_heads
        H = n_heads(cfg)
        b_ax = rules.fsdp if (rules.fsdp and B % rules.n_fsdp == 0) else None
        h_ax = "model" if H % rules.n_model == 0 else None
        shift = NamedSharding(mesh, P(None, b_ax, None, None))
        wkv = NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        return (tok, {"tm_shift": shift, "cm_shift": shift, "wkv": wkv})
    if fam == "hybrid":
        from repro.models.zamba2 import dims
        d_inner, H, Pd, N = dims(cfg)
        b_ax = rules.fsdp if (rules.fsdp and B % rules.n_fsdp == 0) else None
        h_ax = "model" if H % rules.n_model == 0 else None
        i_ax = "model" if d_inner % rules.n_model == 0 else None
        kvh_ax = "model" if cfg.n_kv_heads % rules.n_model == 0 else None
        return (tok, {
            "conv": NamedSharding(mesh, P(None, b_ax, None, i_ax)),
            "ssm": NamedSharding(mesh, P(None, b_ax, h_ax, None, None)),
            "attn_k": NamedSharding(mesh, P(None, b_ax, None, kvh_ax, None)),
            "attn_v": NamedSharding(mesh, P(None, b_ax, None, kvh_ax, None)),
        }, NamedSharding(mesh, P()))
    raise ValueError(fam)
