import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces a JSON record under experiments/dryrun/:
  * compile success/failure (THE multi-pod deliverable),
  * compiled.memory_analysis() — proves the cell fits per-device HBM,
  * compiled.cost_analysis()  — FLOPs/bytes (while-bodies counted once;
    benchmarks/roofline.py corrects with unrolled marginal lowers),
  * per-HLO collective inventory (kind → bytes/count) for the §Roofline
    collective term.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES_BY_NAME, cells
from repro.launch import specs as SPECS
from repro.launch.mesh import make_production_mesh
from repro.parallel.collectives import collective_stats
from repro.parallel.sharding import ShardingRules
from repro.train import optimizer as OPT
from repro.train.step import init_params, make_train_step
from repro.serve.step import make_decode_step, make_prefill_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def param_shapes(cfg):
    return jax.eval_shape(partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def lower_cell(cfg, shape, mesh, mesh_name: str, *, remat: str = "dots",
               accum: int = 1) -> dict:
    rec = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind, "remat": remat, "accum": accum,
        "status": "pending",
    }
    t0 = time.time()
    rules = ShardingRules(mesh)
    p_shapes = param_shapes(cfg)
    p_shard = rules.tree_shardings(p_shapes)

    if shape.kind == "train":
        o_shapes = jax.eval_shape(OPT.init, p_shapes)
        o_shard = OPT.AdamWState(step=_ns(mesh, P()), m=p_shard, v=p_shard)
        batch = SPECS.train_batch_specs(cfg, shape)
        b_shard = SPECS.batch_shardings(batch, rules, mesh)
        step = make_train_step(cfg, accum=accum, remat=remat)
        scalar = _ns(mesh, P())
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           {"loss": scalar, "grad_norm": scalar}),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(p_shapes, o_shapes, batch)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        args = SPECS.prefill_args(cfg, shape)
        arg_sh = tuple(
            _ns(mesh, rules.batch_spec(a.shape[0], a.ndim)) for a in args)
        jitted = jax.jit(step, in_shardings=(p_shard,) + arg_sh)
        with mesh:
            lowered = jitted.lower(p_shapes, *args)
    else:  # decode
        step = make_decode_step(cfg)
        args = SPECS.decode_args(cfg, shape)
        arg_sh = SPECS.decode_shardings(cfg, shape, rules, mesh)
        jitted = jax.jit(step, in_shardings=(p_shard,) + tuple(arg_sh),
                         donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(p_shapes, *args)

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: getattr(ma, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)[:200]}
    try:
        ca = compiled.cost_analysis()
        rec["cost"] = {k: ca[k] for k in ("flops", "bytes accessed")
                       if k in ca}
    except Exception as e:
        rec["cost"] = {"error": str(e)[:200]}
    try:
        rec["collectives"] = collective_stats(compiled.as_text())
    except Exception:
        rec["collectives"] = collective_stats(lowered.as_text())
    rec["n_devices"] = mesh.devices.size
    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def run_cells(cell_list, mesh_names, out_dir: Path, remat: str = "dots"):
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = {}
    results = []
    for name in mesh_names:
        meshes[name] = make_production_mesh(multi_pod=(name == "multi"))
    for cfg, shape, skip in cell_list:
        for mesh_name, mesh in meshes.items():
            out_path = out_dir / f"{cfg.name}__{shape.name}__{mesh_name}.json"
            if skip:
                rec = {"arch": cfg.name, "shape": shape.name,
                       "mesh": mesh_name, "status": "skip", "reason": skip}
            elif out_path.exists():
                print(f"cached  {out_path.name}")
                continue
            else:
                print(f"lower   {cfg.name} × {shape.name} × {mesh_name} ...",
                      flush=True)
                try:
                    rec = lower_cell(cfg, shape, mesh, mesh_name, remat=remat)
                    print(f"  ok    lower {rec['lower_s']}s "
                          f"compile {rec['compile_s']}s", flush=True)
                except Exception as e:
                    rec = {"arch": cfg.name, "shape": shape.name,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                    print(f"  FAIL  {type(e).__name__}: {str(e)[:160]}",
                          flush=True)
            out_path.write_text(json.dumps(rec, indent=1, default=str))
            results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    mesh_names = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
    all_cells = cells()
    # cheap-first ordering: surface systematic failures before the giants
    cost_rank = {"whisper-tiny": 0, "qwen2-0.5b": 1, "gemma-2b": 2,
                 "zamba2-1.2b": 3, "rwkv6-3b": 4, "qwen1.5-4b": 5,
                 "deepseek-7b": 6, "moonshot-v1-16b-a3b": 7,
                 "pixtral-12b": 8, "qwen3-moe-235b-a22b": 9}
    all_cells.sort(key=lambda c: (cost_rank.get(c[0].name, 99),
                                  c[1].seq_len * c[1].global_batch))
    if not args.all:
        if args.arch:
            all_cells = [c for c in all_cells if c[0].name == args.arch]
        if args.shape:
            all_cells = [c for c in all_cells if c[1].name == args.shape]
    results = run_cells(all_cells, mesh_names, Path(args.out),
                        remat=args.remat)
    ok = sum(1 for r in results if r.get("status") == "ok")
    fail = sum(1 for r in results if r.get("status") == "fail")
    skip = sum(1 for r in results if r.get("status") == "skip")
    print(f"\ndone: {ok} ok, {fail} fail, {skip} skip")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
