"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.serve.step import greedy_sample, make_decode_step, make_prefill_step
from repro.train.step import init_params


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0) -> dict:
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    max_seq = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)

    fam = cfg.family
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    t0 = time.time()
    extras = ()
    if fam == "audio":
        frames = jax.random.normal(
            key, (batch, cfg.encoder.n_frames, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype))
        extras = (frames,)
    if fam == "vlm":
        patches = jax.random.normal(
            key, (batch, 8, cfg.d_model), dtype=jnp.dtype(cfg.dtype))
        extras = (patches,)

    generated = []
    if fam in ("dense", "moe"):
        from repro.models.transformer import init_kv_caches, prefill as _pf
        logits, pf_caches = _pf(params, prompts, cfg)
        caches = init_kv_caches(cfg, batch, max_seq)
        caches = jax.tree.map(
            lambda c, p: jax.lax.dynamic_update_slice_in_dim(
                c, p.astype(c.dtype), 0, axis=2), caches, pf_caches)
        token = greedy_sample(logits)
        for i in range(gen):
            generated.append(token)
            logits, caches = decode(params, token,
                                    caches, jnp.int32(prompt_len + i))
            token = greedy_sample(logits)
    elif fam == "ssm":
        from repro.models import rwkv6 as R
        state = R.init_decode_state(cfg, batch)
        # prefill by stepping the recurrence over the prompt (O(S))
        logits = None
        for t in range(prompt_len):
            logits, state = decode(params, prompts[:, t:t + 1], state)
        token = greedy_sample(logits)
        for i in range(gen):
            generated.append(token)
            logits, state = decode(params, token, state)
            token = greedy_sample(logits)
    elif fam == "hybrid":
        from repro.models import zamba2 as Z
        state = Z.init_decode_state(cfg, batch, max_seq)
        logits = None
        for t in range(prompt_len):
            logits, state = decode(params, prompts[:, t:t + 1], state,
                                   jnp.int32(t))
        token = greedy_sample(logits)
        for i in range(gen):
            generated.append(token)
            logits, state = decode(params, token, state,
                                   jnp.int32(prompt_len + i))
            token = greedy_sample(logits)
    else:  # audio / vlm: prefill-only path for the example driver
        logits = prefill(params, prompts, *extras)
        token = greedy_sample(logits)
        if fam == "audio":
            from repro.models import encdec as E
            caches = E.init_kv_caches(cfg, batch, max_seq)
            from repro.models.encdec import encode, precompute_cross_kv
            enc = encode(params, extras[0], cfg)
            xk, xv = precompute_cross_kv(params, enc, cfg)
            caches["xk"], caches["xv"] = xk, xv
            for i in range(gen):
                generated.append(token)
                logits, caches = decode(params, token, caches,
                                        jnp.int32(prompt_len + i))
                token = greedy_sample(logits)
        else:
            from repro.models.transformer import init_kv_caches
            caches = init_kv_caches(cfg, batch, max_seq)
            for i in range(gen):
                generated.append(token)
                logits, caches = decode(params, token, caches,
                                        jnp.int32(prompt_len + i))
                token = greedy_sample(logits)
    out = jnp.concatenate(generated, axis=1) if generated else None
    dt = time.time() - t0
    return {"tokens": out, "elapsed_s": dt,
            "tok_per_s": (batch * gen) / dt if gen else 0.0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    res = serve(args.arch, reduced=args.reduced, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {res['tokens'].shape if res['tokens'] is not None else 0}"
          f" in {res['elapsed_s']:.1f}s ({res['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
