"""End-to-end training driver (example-scale on CPU, mesh-ready for pods).

Integrates the full stack: config registry, sharded params/optimizer,
synthetic data pipeline, AdamW, checkpoint/restart (resumes automatically
from the latest complete step), and optional error-feedback int8 gradient
compression.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ShapeSpec
from repro.launch.mesh import make_local_mesh
from repro.parallel.sharding import ShardingRules
from repro.runtime import checkpoint as CKPT
from repro.train import optimizer as OPT
from repro.train.data import make_batch_fn
from repro.train.step import init_params, make_train_step


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 128, ckpt_dir: str | None = None,
          ckpt_every: int = 20, seed: int = 0, remat: str = "none",
          log_every: int = 10, model_parallel: int = 1) -> dict:
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh(model=model_parallel)
    rules = ShardingRules(mesh)
    shape = ShapeSpec("custom", seq, batch, "train")

    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    opt_state = OPT.init(params)
    p_shard = rules.tree_shardings(params)
    params = jax.tree.map(jax.device_put, params, p_shard)

    start_step = 0
    if ckpt_dir:
        last = CKPT.latest_step(ckpt_dir)
        if last is not None:
            state = CKPT.restore({"params": params, "m": opt_state.m,
                                  "v": opt_state.v,
                                  "step": opt_state.step},
                                 ckpt_dir, last)
            params = state["params"]
            opt_state = OPT.AdamWState(step=state["step"], m=state["m"],
                                       v=state["v"])
            start_step = last
            print(f"resumed from step {last}")

    step_fn = jax.jit(make_train_step(cfg, remat=remat),
                      donate_argnums=(0, 1))
    batch_fn = make_batch_fn(cfg, shape, seed=seed)

    losses = []
    t0 = time.time()
    pending_save = None
    with mesh:
        for step in range(start_step, steps):
            b = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, b)
            if step % log_every == 0 or step == steps - 1:
                l = float(metrics["loss"])
                losses.append((step, l))
                print(f"step {step:5d}  loss {l:.4f}  "
                      f"({time.time() - t0:.1f}s)", flush=True)
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                pending_save = CKPT.save_async(
                    {"params": params, "m": opt_state.m, "v": opt_state.v,
                     "step": opt_state.step}, ckpt_dir, step + 1)
    if pending_save is not None:
        pending_save.join()
    return {"losses": losses, "final_loss": losses[-1][1],
            "first_loss": losses[0][1], "steps": steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    res = train(args.arch, reduced=args.reduced, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, remat=args.remat,
                model_parallel=args.model_parallel)
    print(f"loss {res['first_loss']:.4f} -> {res['final_loss']:.4f}")


if __name__ == "__main__":
    main()
