"""repro.serve — serving layers.

* ``serve.asa`` / ``serve.loop`` — ASA-as-a-service: a jitted, batched
  submit-lead-time decision step over a fixed-slot tenant table of
  device-resident Algorithm-1 posteriors, wrapped in a stdlib
  event-loop shell (request queue → padded batches → one jitted step).
  See ``serve/README.md``.
* ``serve.step`` — KV/SSM state model-serving steps (prefill/decode)
  for the model zoo under ``repro.models``.
"""
