"""repro.serve — KV/SSM state serving steps."""
