"""Live batched ASA decisions: the jitted core of ASA-as-a-service.

The paper's whole point is *proactive* submission — ASA estimates the
queue wait a_y for the next stage and submits it a_y seconds before the
current stage's expected end (§3, Alg. 1).  This module answers that
question as a service: one jitted **decision step** serves a padded batch
of per-tenant queries against a fixed-slot **tenant table** of
device-resident Algorithm-1 posteriors (a batched ``core.asa.ASAState``,
one row per tenant slot).

A query carries (slot, observed_wait?, has_obs):

* **observe** — the tenant saw a stage actually start after
  ``observed_wait`` seconds in the queue.  The slot's posterior takes the
  tuned §4.5 update (``asa.learn_wait_if`` — the exact update the xsim
  engine threads through its scan), consuming the slot's own PRNG key.
* **decide** — every query row answers "how far ahead should the next
  stage be submitted": the MAP wait of the (freshly updated) posterior,
  plus the posterior-mean wait and entropy (``asa.posterior_features``).

Batch semantics: observations scatter first, then every decision reads
the post-scatter table — a request that both observes and decides sees
its own update.  The host batcher (``repro.serve.loop``) guarantees **at
most one observation per slot per batch** (duplicates are deferred to
the next batch), which keeps the scatter well-defined; decisions are
pure reads, so duplicate decision slots are fine.

The ``mesh=`` path shard_maps the *query* axis over a 1-D ``scenarios``
mesh with the table replicated: each device updates its block of query
rows, all-gathers the updated rows, and applies the identical full-batch
scatter — so every device holds the same new table and the result is
bit-identical to the single-device vmap path (pinned by
tests/test_serve_sharded.py on 1/2/4/8 fake devices).

Everything here is pure/functional; threads, queues, tenant admission
and checkpoint cadence live in ``repro.serve.loop``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map

from repro.core import asa
from repro.core.bins import make_bins


class ServeStepError(RuntimeError):
    """One batch's jitted decision step failed.

    The serve loop raises this INTO the batch's futures — containment is
    per batch, the loop itself survives (``__cause__`` carries the device
    exception; ``batch`` the dispatched-batch index).  Clients retry; the
    tenant table holds its pre-dispatch state when the failure happened
    at dispatch (the functional update never landed)."""

    def __init__(self, msg: str, *, batch: int = -1):
        super().__init__(msg)
        self.batch = batch


class QueryBatch(NamedTuple):
    """One padded batch of tenant queries (all leaves shaped (B,))."""

    slot: jax.Array           # i32 tenant-table slot per query
    observed_wait: jax.Array  # f32 observed queue wait (seconds)
    has_obs: jax.Array        # bool: this query carries an observation


class DecisionBatch(NamedTuple):
    """Per-query answers (all (B,)); rows where the pad mask is False
    are computed against slot 0's copies and must be discarded."""

    lead_s: jax.Array      # MAP wait: the submit-lead-time ASA acts on
    expected_s: jax.Array  # posterior-mean wait ⟨p, θ⟩
    entropy: jax.Array     # Shannon entropy of p (how much ASA hedges)


def init_table(n_slots: int, m: int = 53, seed: int = 0) -> asa.ASAState:
    """The fixed-slot tenant table: ``n_slots`` independent Algorithm-1
    estimators with per-slot PRNG keys (a batched ``ASAState``)."""
    return asa.init_batch(m, n_slots, jax.random.PRNGKey(seed))


@jax.jit
def reset_slot(table: asa.ASAState, slot: jax.Array,
               key: jax.Array) -> asa.ASAState:
    """Re-initialise one slot (tenant eviction → slot reuse): the row
    returns to the uniform p_0 = 1/m prior with a fresh PRNG key."""
    m = table.log_p.shape[-1]
    fresh = asa.init(m, key)
    return jax.tree.map(lambda t, f: t.at[slot].set(f), table, fresh)


def _update_body(table: asa.ASAState, q: QueryBatch, mask: jax.Array,
                 scatter_rows=None) -> asa.ASAState:
    """Apply the batch's observations to the table.

    ``scatter_rows`` post-processes the locally-updated rows before the
    scatter — the sharded path all-gathers them so every device applies
    the identical full-batch write; the vmap path scatters them as-is.
    """
    m = table.log_p.shape[-1]
    n = table.log_p.shape[0]
    bins = jnp.asarray(make_bins(m), jnp.float32)
    slot = jnp.clip(q.slot, 0, n - 1)

    # observations: gather each query's row, apply the tuned §4.5
    # update where the query carries one (learn_wait_if is a no-op —
    # PRNG included — on the False branch)
    rows = jax.tree.map(lambda x: x[slot], table)
    do = mask & q.has_obs
    upd = jax.vmap(asa.learn_wait_if, in_axes=(0, None, 0, 0))(
        rows, bins, q.observed_wait, do)

    # scatter the updated rows back; non-observing rows target index n
    # (mode="drop"), so only real observations touch the table
    tgt = jnp.where(do, slot, n)
    if scatter_rows is not None:
        tgt, upd = scatter_rows(tgt, upd)
    return jax.tree.map(
        lambda t, u: t.at[tgt].set(u, mode="drop"), table, upd)


_apply_updates = jax.jit(_update_body)


@jax.jit
def _read_decisions(table: asa.ASAState, q: QueryBatch) -> DecisionBatch:
    """Answer every query row from the (post-scatter) table.

    Deliberately its own compiled program, shared by the vmap and the
    shard_map paths: the posterior-mean ⟨p, θ⟩ is a float reduction, and
    XLA may vectorize the same reduction differently at different batch
    widths (a 1-ULP wiggle) — running the one full-batch program on the
    replicated table makes the sharded decisions bit-identical to the
    single-device ones by construction, not by luck.
    """
    m = table.log_p.shape[-1]
    n = table.log_p.shape[0]
    bins = jnp.asarray(make_bins(m), jnp.float32)
    slot = jnp.clip(q.slot, 0, n - 1)
    fresh = jax.tree.map(lambda x: x[slot], table)
    feats = jax.vmap(asa.posterior_features, in_axes=(0, None))(fresh, bins)
    return DecisionBatch(
        lead_s=feats[:, 0], expected_s=feats[:, 1], entropy=feats[:, 2])


def decision_step(table: asa.ASAState, q: QueryBatch, mask: jax.Array
                  ) -> tuple[asa.ASAState, DecisionBatch]:
    """One batched decision step (single-device vmap path): scatter the
    observations, then answer every query from the post-scatter table —
    a query that both observes and decides sees its own update.

    ``mask`` is the validity mask from ``parallel.fleet.pad_batch`` —
    pad rows (copies of query 0) never update the table and their
    decision rows are garbage to be sliced off by the caller.
    """
    table = _apply_updates(table, q, mask)
    return table, _read_decisions(table, q)


@functools.lru_cache(maxsize=None)
def _sharded_update_fn(mesh):
    """Compiled shard_map of the update half for one mesh (cached, as
    ``xsim.events._sharded_sweep_fn`` caches its sweeps). Only the
    per-row posterior updates are sharded; the decision read runs in
    the shared ``_read_decisions`` program afterwards."""
    from repro.parallel import fleet as pfleet

    spec = pfleet.shard_spec()
    rep = pfleet.replicated_spec()

    def block(table: asa.ASAState, q: QueryBatch, mask: jax.Array):
        def gather_all(tgt, upd):
            # every device applies the FULL batch's scatter so the
            # replicated table stays identical everywhere — tiled
            # all_gather concatenates the blocks in mesh order, i.e. the
            # original batch order, so the write is bit-identical to the
            # single-device scatter
            tgt = jax.lax.all_gather(tgt, pfleet.SCENARIO_AXIS, tiled=True)
            upd = jax.tree.map(
                lambda x: jax.lax.all_gather(
                    x, pfleet.SCENARIO_AXIS, tiled=True), upd)
            return tgt, upd

        return _update_body(table, q, mask, scatter_rows=gather_all)

    fn = shard_map(block, mesh=mesh, in_specs=(rep, spec, spec),
                   out_specs=rep, check_rep=False)
    return jax.jit(fn)


def decisions_to_host(dec: DecisionBatch
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bring a ``DecisionBatch`` to host in ONE device→host sync.

    ``np.asarray`` per field costs three round-trips to the device
    stream; ``jax.device_get`` on the whole tuple blocks once.  This is
    also the serve loop's *scatter-read* instrumentation point: the call
    blocks until the dispatched ``serve_step`` actually finishes, so the
    time spent here is the host-blocked device wait
    (``obs.serve_obs`` records it as the ``scatter_read`` span, distinct
    from the async ``device_step`` dispatch)."""
    lead, expected, entropy = jax.device_get(
        (dec.lead_s, dec.expected_s, dec.entropy))
    return np.asarray(lead), np.asarray(expected), np.asarray(entropy)


def serve_step(table: asa.ASAState, q: QueryBatch, mask: jax.Array, *,
               mesh=None) -> tuple[asa.ASAState, DecisionBatch]:
    """Dispatch one padded query batch: vmap path (``mesh=None``) or the
    bit-identical shard_map path over a 1-D ``scenarios`` mesh (build it
    with ``launch.mesh.make_scenarios_mesh``; the batch's leading axis
    must be divisible by the mesh size — ``loop.ServeConfig`` enforces
    ``batch_size % n_shards == 0``). Both paths answer through the one
    ``_read_decisions`` program, so equal tables give equal decisions
    bit for bit."""
    if mesh is None:
        return decision_step(table, q, mask)
    table = _sharded_update_fn(mesh)(table, q, mask)
    return table, _read_decisions(table, q)
