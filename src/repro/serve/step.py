"""Family-dispatched serving steps: prefill and single-token decode.

``decode_*`` shapes lower THESE functions (one new token against a KV cache
/ recurrent state of seq_len), never train_step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def make_prefill_step(cfg: ModelConfig, *, use_flash: bool = False,
                      unroll: bool = False):
    fam = cfg.family

    if fam in ("dense", "moe"):
        from repro.models import transformer as T

        def prefill(params, tokens):
            return T.prefill(params, tokens, cfg, use_flash=use_flash,
                             unroll=unroll)
        return prefill

    if fam == "vlm":
        from repro.models import transformer as T

        def prefill(params, tokens, patch_embeds):
            logits = T.forward(params, tokens, cfg,
                               prefix_embeds=patch_embeds,
                               use_flash=use_flash, unroll=unroll)
            return logits[:, -1:, :]
        return prefill

    if fam == "audio":
        from repro.models import encdec as E

        def prefill(params, tokens, frames):
            enc = E.encode(params, frames, cfg, unroll=unroll)
            return E.decode_train(params, tokens, enc, cfg,
                                  unroll=unroll)[:, -1:, :]
        return prefill

    if fam == "ssm":
        from repro.models import rwkv6 as R

        def prefill(params, tokens):
            return R.forward(params, tokens, cfg, unroll=unroll)[:, -1:, :]
        return prefill

    if fam == "hybrid":
        from repro.models import zamba2 as Z

        def prefill(params, tokens):
            return Z.forward(params, tokens, cfg, unroll=unroll)[:, -1:, :]
        return prefill

    raise ValueError(fam)


def make_decode_step(cfg: ModelConfig, *, unroll: bool = False):
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as T

        def decode(params, token, caches, index):
            return T.decode_step(params, token, caches, index, cfg,
                                 unroll=unroll)
        return decode

    if fam == "audio":
        from repro.models import encdec as E

        def decode(params, token, caches, index):
            return E.decode_step(params, token, caches, index, cfg,
                                 unroll=unroll)
        return decode

    if fam == "ssm":
        from repro.models import rwkv6 as R

        def decode(params, token, state):
            return R.decode_step(params, token, state, cfg, unroll=unroll)
        return decode

    if fam == "hybrid":
        from repro.models import zamba2 as Z

        def decode(params, token, state, index):
            return Z.decode_step(params, token, state, index, cfg)
        return decode

    raise ValueError(fam)


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
