"""Deterministic seeded fault injection for the ASA serving loop.

``runtime.fault`` gives the *simulator* reproducible capacity faults as
validated, time-sorted schedule data; this module gives the *server* the
same treatment.  A :class:`ChaosSchedule` is a frozen, validated,
batch-sorted tuple of :class:`ChaosEvent` rows; a :class:`ChaosInjector`
consumes it against a live :class:`repro.serve.loop.ASAServer` through
test-only hooks the loop calls at three seams:

* **batch boundary** (``on_batch_boundary``, top of ``step_once`` before
  any request is picked up) — fires ``queue_burst`` (the injector
  submits a seeded burst of synthetic-tenant requests through the public
  ``submit`` path, so bursts exercise bounded ingress/shedding exactly
  like real traffic) and ``crash_kill_between_batches`` (raises
  :class:`InjectedCrash`, which escapes ``step_once`` and kills the loop
  thread — the supervisor's restart path);
* **before the device step** (``before_device_step``, inside the
  containment region) — ``step_exception`` raises
  :class:`InjectedStepFault` (wrapped into ``serve.asa.ServeStepError``
  and failed into that batch's futures; the loop survives) and
  ``slow_device_step`` sleeps ``magnitude`` seconds (a stuck device:
  exercises the last-batch-age watchdog);
* **checkpoint cadence** (``on_checkpoint``) — ``checkpoint_write_error``
  raises ``OSError`` at the save site (contained: counted, serving
  continues; the on-disk latest stays the previous good step).

Event firing is **at-or-after** semantics keyed on the server's
dispatched-batch counter: an event fires at the first hook call where
``batches >= event.batch`` and never again — deterministic for a given
schedule + seed + traffic, regardless of how many empty drains happen
in between.  Everything here is test/bench-only: a server built without
an injector has zero chaos branches on its hot path beyond one ``is not
None`` check per batch.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

CHAOS_KINDS = ("step_exception", "slow_device_step",
               "checkpoint_write_error", "crash_kill_between_batches",
               "queue_burst")


class InjectedStepFault(RuntimeError):
    """Raised inside the device-step containment region: the loop wraps
    it into ``serve.asa.ServeStepError`` and fails that batch only."""


class InjectedCrash(RuntimeError):
    """Raised at a batch boundary: escapes ``step_once``, kills the loop
    thread, and exercises the supervisor's restore-and-restart path."""


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault.

    ``batch`` — dispatched-batch index the event arms at (at-or-after);
    ``kind`` — one of :data:`CHAOS_KINDS`;
    ``magnitude`` — sleep seconds for ``slow_device_step``, request
    count for ``queue_burst``, unused (0) otherwise.
    """

    batch: int
    kind: str
    magnitude: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(valid: {CHAOS_KINDS})")
        if self.batch < 0:
            raise ValueError(f"{self.kind}: batch must be >= 0, "
                             f"got {self.batch}")
        if self.magnitude < 0:
            raise ValueError(f"{self.kind}: magnitude must be >= 0, "
                             f"got {self.magnitude}")
        if self.kind == "slow_device_step" and self.magnitude <= 0:
            raise ValueError("slow_device_step needs magnitude > 0 "
                             "(the stall seconds)")
        if self.kind == "queue_burst" and self.magnitude < 1:
            raise ValueError("queue_burst needs magnitude >= 1 "
                             "(the burst request count)")


@dataclass(frozen=True)
class ChaosSchedule:
    """A validated, batch-sorted fault schedule (the ``FaultSchedule``
    idiom: frozen data, sorted in ``__post_init__``, duplicates of the
    same (batch, kind) rejected so firing order is total)."""

    events: tuple[ChaosEvent, ...] = ()

    def __post_init__(self) -> None:
        evs = tuple(sorted(self.events,
                           key=lambda e: (e.batch, CHAOS_KINDS.index(e.kind))))
        seen: set[tuple[int, str]] = set()
        for e in evs:
            k = (e.batch, e.kind)
            if k in seen:
                raise ValueError(f"duplicate chaos event {e.kind!r} at "
                                 f"batch {e.batch}")
            seen.add(k)
        object.__setattr__(self, "events", evs)

    def __len__(self) -> int:
        return len(self.events)


def step_exception(batch: int) -> ChaosEvent:
    return ChaosEvent(batch, "step_exception")


def slow_step(batch: int, seconds: float) -> ChaosEvent:
    return ChaosEvent(batch, "slow_device_step", seconds)


def checkpoint_error(batch: int) -> ChaosEvent:
    return ChaosEvent(batch, "checkpoint_write_error")


def crash(batch: int) -> ChaosEvent:
    return ChaosEvent(batch, "crash_kill_between_batches")


def queue_burst(batch: int, n: int) -> ChaosEvent:
    return ChaosEvent(batch, "queue_burst", float(n))


# synthetic burst tenants start here: far above any loadgen tenant id
# but well inside int32 (the tenant-id array the checkpoint round-trips)
BURST_TENANT_BASE = 1 << 20


@dataclass
class ChaosInjector:
    """Consumes one :class:`ChaosSchedule` against a live server.

    Carries across supervisor restarts on purpose: events not yet fired
    before a crash fire against the restarted server (the schedule
    describes the *process lifetime*, not one loop incarnation).
    ``fired`` records ``(batch, event, wall_s)`` for every event as it
    fires — the soak derives per-fault recovery times from it — and
    ``burst_futures`` collects every future the injector itself
    submitted, so harnesses can assert the zero-hung-futures invariant
    over injected traffic too.
    """

    schedule: ChaosSchedule
    seed: int = 0
    fired: list = field(default_factory=list)
    burst_futures: list = field(default_factory=list)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._armed = list(self.schedule.events)

    def _take(self, batches: int, kinds: tuple[str, ...]) -> list[ChaosEvent]:
        hit = [e for e in self._armed
               if e.batch <= batches and e.kind in kinds]
        for e in hit:
            self._armed.remove(e)
        return hit

    def record(self, ev: ChaosEvent, wall_s: float) -> None:
        self.fired.append((ev.batch, ev, wall_s))

    # ------------------------------------------------------------- hooks
    def on_batch_boundary(self, server) -> None:
        """Top of ``step_once``: bursts first (they land in the queue the
        crash cleanup drains), then the crash."""
        import time
        batches = server._batches
        for ev in self._take(batches, ("queue_burst",)):
            self.record(ev, time.monotonic())
            for _ in range(int(ev.magnitude)):
                tenant = BURST_TENANT_BASE + self._rng.randrange(1 << 16)
                wait = self._rng.uniform(10.0, 4000.0)
                self.burst_futures.append(server.submit(tenant, wait))
        for ev in self._take(batches, ("crash_kill_between_batches",)):
            self.record(ev, time.monotonic())
            raise InjectedCrash(
                f"chaos: crash_kill_between_batches at batch {batches}")

    def before_device_step(self, batches: int) -> None:
        """Inside the containment region, just before dispatch."""
        import time
        for ev in self._take(batches, ("slow_device_step",)):
            self.record(ev, time.monotonic())
            time.sleep(ev.magnitude)
        for ev in self._take(batches, ("step_exception",)):
            self.record(ev, time.monotonic())
            raise InjectedStepFault(
                f"chaos: step_exception at batch {batches}")

    def on_checkpoint(self, batches: int) -> None:
        """At the cadenced save site, before ``save_async``."""
        import time
        for ev in self._take(batches, ("checkpoint_write_error",)):
            self.record(ev, time.monotonic())
            raise OSError(
                f"chaos: checkpoint_write_error at batch {batches}")

    # ----------------------------------------------------------- derived
    @property
    def pending(self) -> tuple[ChaosEvent, ...]:
        """Events not yet fired (a finished soak asserts this is empty)."""
        return tuple(self._armed)

    def counts(self) -> dict[str, int]:
        out = {k: 0 for k in CHAOS_KINDS}
        for _b, ev, _t in self.fired:
            out[ev.kind] += 1
        return out


def mix_schedule(n_batches: int, seed: int = 0, *,
                 step_exceptions: int = 3, slow_steps: int = 1,
                 checkpoint_errors: int = 2, crashes: int = 1,
                 bursts: int = 2, burst_size: int = 64,
                 slow_s: float = 0.05) -> ChaosSchedule:
    """The soak's standard fault mix, spread deterministically over
    ``n_batches`` dispatched batches (seeded, collision-free)."""
    rng = random.Random(seed)
    events: list[ChaosEvent] = []
    used: set[tuple[int, str]] = set()

    def place(kind: str, count: int, make) -> None:
        for _ in range(count):
            for _try in range(64):
                b = rng.randrange(1, max(2, n_batches))
                if (b, kind) not in used:
                    used.add((b, kind))
                    events.append(make(b))
                    break

    place("step_exception", step_exceptions, step_exception)
    place("slow_device_step", slow_steps, lambda b: slow_step(b, slow_s))
    place("checkpoint_write_error", checkpoint_errors, checkpoint_error)
    place("crash_kill_between_batches", crashes, crash)
    place("queue_burst", bursts, lambda b: queue_burst(b, burst_size))
    return ChaosSchedule(tuple(events))
