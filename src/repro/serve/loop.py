"""Event-loop shell around the jitted ASA decision core.

Architecture follows the AWS ParallelCluster daemon split the ROADMAP
names (sqswatcher/nodewatcher: a thin event-queue-driven shell making
scale decisions around a core): pure stdlib threading — producers
``submit()`` requests into a ``queue.Queue``; the serve loop drains up
to ``batch_size`` of them, pads the batch with
``parallel.fleet.pad_batch``, dispatches ONE jitted
``serve.asa.serve_step`` (vmap, or shard_map when ``n_shards`` is set),
and resolves each request's ``concurrent.futures.Future`` with its
:class:`Decision`.

Host-side responsibilities (everything the jitted core must not know):

* **tenant admission** — tenant ids map to fixed table slots; a new
  tenant takes a free slot (fresh slots were initialised at table build;
  reused slots are reset through ``serve.asa.reset_slot`` with a fresh
  fold_in key).  A full table raises :class:`TableFullError` into the
  request's future, never into the loop — unless
  ``ServeConfig.tenant_ttl_s`` is set, in which case slots are **leased**
  through ``runtime.pool`` (claimed at admit, the lease refreshed on
  every request) and a full table first sweeps lapsed leases, then
  sheds the *coldest* idle tenant (oldest lease deadline) instead of
  erroring; tenants with rows already in the forming batch are never
  shed (one slot must not serve two tenants inside one scatter).
* **observation dedup** — the decision core requires at most one
  observation per slot per batch (the scatter must be well-defined).
  The batcher defers a tenant's second same-batch observation — and
  every later request of that tenant, preserving per-tenant order — to
  the next batch.
* **checkpoint cadence** — every ``checkpoint_every`` batches the server
  snapshots ``{table, tenant_ids, admissions, dirty}`` through
  ``runtime.checkpoint``
  (``save_async``; the previous handle's ``result()`` is collected first
  so a failed background save surfaces in the serve loop, not silently).
  ``ASAServer.restore`` resumes a server whose posteriors — PRNG keys
  included — are bitwise what the saved server held, so restarted
  decisions are bit-identical (pinned by tests/test_serve.py).
* **observability** — every server carries a
  :class:`repro.obs.serve_obs.ServeObs`: an always-on
  ``obs.registry`` metric set (``stats`` is a view over it; the
  Prometheus/JSON scrape endpoint below exposes it live) plus
  request-lifecycle span recording that is **off by default**
  (``ServeConfig.obs_spans``) — with spans off no timestamps are taken
  and the decision path is bit-identical to the uninstrumented server.
  ``serve_metrics_http()`` serves ``GET /metrics`` (Prometheus text),
  ``/metrics.json`` (registry snapshot) and ``/stats`` on a stdlib
  ``ThreadingHTTPServer`` — no new dependencies.

Fault tolerance (the crash-safe lifecycle; see serve/README.md for the
failure-modes table):

* **a failing jitted step fails that batch, not the loop** — every
  exception between batch-form and the host decision read resolves the
  batch's futures with a typed :class:`repro.serve.asa.ServeStepError`
  (``__cause__`` carries the device exception) and the loop keeps
  serving; the table keeps its pre-dispatch state (the functional
  update is only committed after the host read succeeds).
* **a crashed loop strands nothing** — any exception escaping the batch
  loop fails every queued/deferred future with :class:`ServerCrashed`,
  flips ``asa_serve_loop_healthy`` to 0 and signals
  :class:`ServeSupervisor`, which restores from the latest **verified**
  checkpoint and restarts; nothing is replayed (crashed requests were
  failed with typed errors — clients resubmit, and the restored
  posteriors answer bitwise what the uninterrupted server would have).
* **stop() is a drain, not an abandonment** — queued/deferred futures
  fail with :class:`ServerStopped`; ``submit()`` after ``stop()``
  raises immediately; repeated ``stop()`` is idempotent, and ``start()``
  brings a stopped server back.
* **pressure sheds, never hangs** — ``ServeConfig.max_queue`` bounds
  ingress (overflow fails the future with :class:`QueueFullError` at
  submit), ``submit(deadline_s=...)`` requests are shed at batch-form
  once expired (:class:`RequestExpired`), and every shed is counted
  (``asa_serve_shed_total`` + per-reason counters).
* **chaos hooks** — a :class:`repro.serve.chaos.ChaosInjector` passed at
  construction is consulted at the batch boundary, before the device
  step, and at checkpoint cadence; servers built without one pay a
  single ``is not None`` check per batch.

The registry is deliberately **not** part of the checkpoint: counters
describe this process's lifetime, not the estimator state; a restored
server starts its counters at zero while answering bitwise-identically.
"""

from __future__ import annotations

import json
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.core import asa as core_asa
from repro.obs.serve_obs import ServeObs
from repro.parallel import fleet as pfleet
from repro.runtime import checkpoint
from repro.runtime.pool import Claim, ResourcePool
from repro.serve import asa as serve_asa


class TableFullError(RuntimeError):
    """Every tenant slot is occupied; evict a tenant first (or run with
    ``ServeConfig.tenant_ttl_s`` so pressure sheds the coldest lease)."""


class ServerStopped(RuntimeError):
    """The server was stopped: raised by ``submit()`` after ``stop()``,
    and failed into every future ``stop()`` drained."""


class ServerCrashed(RuntimeError):
    """The serve loop died: failed into every queued/deferred future at
    crash time (``__cause__`` carries the loop's exception) and raised
    by ``submit()`` against the dead incarnation."""


class QueueFullError(RuntimeError):
    """Bounded ingress (``ServeConfig.max_queue``) shed this request at
    submit time; resubmit with backoff."""


class RequestExpired(RuntimeError):
    """The request's ``deadline_s`` passed before batch formation; the
    decision would have arrived too late to act on, so it was shed."""


@dataclass(frozen=True)
class ServeConfig:
    """Static server parameters (one compiled step per config)."""

    n_slots: int = 1024        # fixed tenant-table capacity
    m: int = 53                # wait-bin count (paper §4.3)
    batch_size: int = 256      # queries per jitted step (the padded shape)
    n_shards: Optional[int] = None  # shard_map the query axis over N devices
    batch_wait_s: float = 0.002     # max idle wait for the first request
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0  # batches between async snapshots (0 = off)
    seed: int = 0
    obs_spans: bool = False    # record request-lifecycle spans (wall-clock)
    metrics_port: Optional[int] = None  # start() scrapes here (0 = any)
    max_queue: Optional[int] = None  # bounded ingress (None = unbounded)
    tenant_ttl_s: Optional[float] = None  # slot-lease TTL (None = no leases)

    def __post_init__(self) -> None:
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")
        if self.n_shards is not None and \
                self.batch_size % self.n_shards != 0:
            raise ValueError(
                f"batch_size {self.batch_size} not divisible by n_shards "
                f"{self.n_shards}: the padded batch must split evenly "
                "over the mesh")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError("checkpoint_every set without checkpoint_dir")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be >= 1 (or None), got {self.max_queue}")
        if self.tenant_ttl_s is not None and self.tenant_ttl_s <= 0:
            raise ValueError(
                f"tenant_ttl_s must be > 0 (or None), "
                f"got {self.tenant_ttl_s}")


@dataclass
class Request:
    """One tenant query: an optional observed stage wait to learn from,
    and (always) the submit-lead-time decision for the next stage.

    ``deadline_s`` is an *absolute* ``time.monotonic()`` deadline
    (stamped by ``submit(deadline_s=...)`` from the relative value);
    ``rid``/``t_enqueue`` are observability bookkeeping stamped by
    ``submit()`` when span recording is on (-1/0.0 otherwise)."""

    tenant: int
    observed_wait: Optional[float] = None
    deadline_s: Optional[float] = None
    rid: int = -1
    t_enqueue: float = 0.0


@dataclass
class Decision:
    """The answer: submit the next stage ``lead_s`` seconds before the
    current stage's expected end (MAP wait); ``expected_s``/``entropy``
    report the posterior mean and how much the estimator still hedges."""

    tenant: int
    lead_s: float
    expected_s: float
    entropy: float


class ASAServer:
    """Batched ASA decision service over a fixed-slot tenant table."""

    def __init__(self, cfg: ServeConfig, mesh=None,
                 obs: Optional[ServeObs] = None, chaos=None):
        self.cfg = cfg
        if mesh is None and cfg.n_shards is not None:
            from repro.launch.mesh import make_scenarios_mesh
            mesh = make_scenarios_mesh(cfg.n_shards)
        self._mesh = mesh
        self._obs = obs if obs is not None else \
            ServeObs(spans=cfg.obs_spans)
        self._chaos = chaos
        self._table = serve_asa.init_table(cfg.n_slots, cfg.m, cfg.seed)
        # host-side tenant bookkeeping: the (n_slots,) id array is part of
        # the checkpointed state; the dict/free-list are derived views.
        # int32 on purpose: the checkpoint codec restores through jnp,
        # which is 32-bit without x64 — tenant ids must fit i32
        self._tenant_ids = np.full(cfg.n_slots, -1, np.int32)
        self._slot_of: dict[int, int] = {}
        self._free: deque[int] = deque(range(cfg.n_slots))
        self._dirty: set[int] = set()   # freed slots needing a reset
        self._admissions = 0            # salts reset keys
        self._requests_of: dict[int, int] = {}  # per-tenant lifetime count
        self._queue: "queue.Queue[tuple[Request, Future]]" = queue.Queue()
        self._deferred: deque[tuple[Request, Future]] = deque()
        self._batches = 0
        self._ckpt_handle: Optional[checkpoint.AsyncSave] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._http: Optional[ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        # ingress gate: submit() checks the lifecycle flags and enqueues
        # under this lock; stop()/crash drain under it too — so no
        # producer can slip a future into a queue that was already
        # drained (the no-hung-futures invariant)
        self._ingress_lock = threading.Lock()
        self._stopped = False
        self._crashed: Optional[BaseException] = None
        self._crash_event = threading.Event()
        self._last_batch_ts = time.monotonic()
        # slot leases (tenant_ttl_s): one pool allocation covers the
        # table; each admitted tenant claims 1 slice with an expiry the
        # serving path refreshes — sweep/LRU shed both run off it
        self._pool: Optional[ResourcePool] = None
        self._lease_of: dict[int, Claim] = {}
        self._tenant_of_claim: dict[int, int] = {}
        if cfg.tenant_ttl_s is not None:
            self._pool = ResourcePool()
            self._pool.add_allocation(cfg.n_slots)
            self._pool.on_revoke.append(self._on_lease_revoked)
        self._obs.g_free_slots.set(len(self._free))
        # fn-backed watchdog: the age keeps growing while the loop is
        # stuck, which is exactly when nothing would push a plain gauge
        self._obs.g_last_batch_age.set_fn(
            lambda: max(0.0, time.monotonic() - self._last_batch_ts))

    @property
    def obs(self) -> ServeObs:
        """The server's registry + span recorder (always present)."""
        return self._obs

    # ------------------------------------------------------------ tenants
    @property
    def n_tenants(self) -> int:
        return len(self._slot_of)

    def _grant_lease(self, tenant: int, now: float) -> None:
        lease = self._pool.claim(
            1, expires_at=now + self.cfg.tenant_ttl_s)
        if lease is not None:  # pool mirrors _free; None only if skewed
            self._lease_of[tenant] = lease
            self._tenant_of_claim[lease.id] = tenant

    def _drop_lease(self, tenant: int) -> None:
        lease = self._lease_of.pop(tenant, None)
        if lease is not None:
            self._tenant_of_claim.pop(lease.id, None)
            self._pool.release(lease)   # no-op if already lapsed

    def _on_lease_revoked(self, lease: Claim) -> None:
        # sweep_expired lapsed an idle tenant's lease: evict it (the
        # sweep already released the slices; evict frees the table slot)
        tenant = self._tenant_of_claim.pop(lease.id, None)
        if tenant is None:
            return
        self._lease_of.pop(tenant, None)
        if tenant in self._slot_of:
            self.evict(tenant)
            self._obs.c_lease_evictions.inc()

    def _shed_coldest(self, protected) -> None:
        """Table full under leases: evict the idlest tenant (oldest
        lease deadline; ties by claim id — deterministic), never one
        whose request already holds a row in the forming batch."""
        cands = [(c.expires_at, c.id, t) for t, c in self._lease_of.items()
                 if t not in protected]
        if not cands:
            return
        _, _, victim = min(cands)
        self.evict(victim)   # evict() drops the lease
        self._obs.c_lease_evictions.inc()
        self._obs.instant("lease_evict", self._obs.now(),
                          {"tenant": victim, "reason": "pressure"})

    def _admit(self, tenant: int, protected=frozenset()) -> int:
        if self._pool is not None:
            now = time.monotonic()
            self._pool.sweep_expired(now)   # on_revoke evicts idle tenants
            if not self._free:
                self._shed_coldest(protected)
        if not self._free:
            raise TableFullError(
                f"all {self.cfg.n_slots} tenant slots occupied")
        slot = self._free.popleft()
        if slot in self._dirty:
            # slot reuse: back to the uniform prior with a fresh key
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.cfg.seed ^ 0x5A5A5A5A),
                self._admissions)
            self._table = serve_asa.reset_slot(self._table, slot, key)
            self._dirty.discard(slot)
        self._admissions += 1
        self._slot_of[tenant] = slot
        self._tenant_ids[slot] = tenant
        if self._pool is not None:
            self._grant_lease(tenant, now)
        o = self._obs
        o.c_admissions.inc()
        o.g_tenants.set(len(self._slot_of))
        o.g_free_slots.set(len(self._free))
        o.instant("admit", o.now(), {"tenant": tenant, "slot": slot})
        return slot

    def evict(self, tenant: int) -> None:
        """Free a tenant's slot (its posterior resets on slot reuse).

        The tenant's lifetime request total is snapshotted into the
        registry (``asa_serve_evicted_requests_total``) at this moment,
        so fleet accounting survives the eviction — ``stats`` no longer
        silently loses an evicted tenant's counts."""
        if self._pool is not None:
            self._drop_lease(tenant)
        slot = self._slot_of.pop(tenant)
        self._tenant_ids[slot] = -1
        self._dirty.add(slot)
        self._free.append(slot)
        lifetime = self._requests_of.pop(tenant, 0)
        o = self._obs
        o.c_evictions.inc()
        o.c_evicted_requests.inc(lifetime)
        o.g_tenants.set(len(self._slot_of))
        o.g_free_slots.set(len(self._free))
        o.instant("evict", o.now(),
                  {"tenant": tenant, "slot": slot, "requests": lifetime})

    # ------------------------------------------------------------ serving
    def submit(self, tenant: int,
               observed_wait: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Future:
        """Enqueue one request; the future resolves to a Decision (or a
        typed error — never hangs).  ``deadline_s`` is relative seconds:
        a request still queued that long past now is shed with
        :class:`RequestExpired` instead of dispatched uselessly late.
        Raises :class:`ServerStopped`/:class:`ServerCrashed` immediately
        against a dead server; a full bounded queue *fails the future*
        with :class:`QueueFullError` (shedding, not an API error)."""
        fut: Future = Future()
        req = Request(tenant, observed_wait)
        if deadline_s is not None:
            req.deadline_s = time.monotonic() + deadline_s
        o = self._obs
        with self._ingress_lock:
            if self._crashed is not None:
                raise ServerCrashed(
                    "serve loop crashed; restore/restart before "
                    "submitting") from self._crashed
            if self._stopped:
                raise ServerStopped(
                    "server is stopped: submit() rejected")
            o.c_requests.inc()
            o.g_inflight.inc()
            if observed_wait is not None:
                o.c_observations.inc()
            if o.spans:
                req.rid = o.next_rid()
                req.t_enqueue = time.perf_counter()
                o.enqueue(req.rid, tenant, req.t_enqueue)
            if (self.cfg.max_queue is not None
                    and self._queue.qsize() >= self.cfg.max_queue):
                o.c_shed.inc()
                o.c_shed_queue_full.inc()
                fut.set_exception(QueueFullError(
                    f"ingress queue at max_queue={self.cfg.max_queue}; "
                    f"request for tenant {tenant} shed"))
                o.resolve(req.rid, tenant, req.t_enqueue, o.now(),
                          error="queue_full")
                return fut
            self._queue.put((req, fut))
        return fut

    def _drain(self, wait_s: float) -> list[tuple[Request, Future]]:
        """Pull queued requests into the deferred deque, then pick the
        next batch in order — shedding expired-deadline requests, and
        deferring any tenant whose second same-batch observation would
        break the unique-scatter invariant."""
        pending = self._deferred
        timeout = wait_s if not pending else 0.0
        while True:
            try:
                item = (self._queue.get(timeout=timeout)
                        if timeout > 0 else self._queue.get_nowait())
            except queue.Empty:
                break
            pending.append(item)
            timeout = 0.0
        batch: list[tuple[Request, Future]] = []
        held: deque[tuple[Request, Future]] = deque()
        obs_seen: set[int] = set()
        blocked: set[int] = set()
        o = self._obs
        t_d = o.now()  # one defer timestamp per drain: deferral events
        #                are batch-granular, a clock read each is not free
        now_mono = time.monotonic()  # one deadline check point per drain
        while pending and len(batch) < self.cfg.batch_size:
            req, fut = pending.popleft()
            if req.deadline_s is not None and now_mono >= req.deadline_s:
                # too late to act on the decision: shed at batch-form
                fut.set_exception(RequestExpired(
                    f"tenant {req.tenant}: deadline passed "
                    f"{now_mono - req.deadline_s:.3f}s before batch "
                    "formation"))
                o.c_shed.inc()
                o.c_shed_expired.inc()
                o.resolve(req.rid, req.tenant, req.t_enqueue, t_d,
                          error="expired")
                continue
            if req.tenant in blocked:
                o.defer(req.rid, req.tenant, t_d)
                held.append((req, fut))
                continue
            if req.observed_wait is not None:
                if req.tenant in obs_seen:
                    # second observation for this slot: defer it (and all
                    # later requests of this tenant — order preserved)
                    blocked.add(req.tenant)
                    o.defer(req.rid, req.tenant, t_d)
                    held.append((req, fut))
                    continue
                obs_seen.add(req.tenant)
            batch.append((req, fut))
        held.extend(pending)
        self._deferred = held
        o.g_deferred.set(len(held))
        return batch

    def step_once(self, wait_s: Optional[float] = None) -> int:
        """Drain + dispatch one batch; returns the number of requests
        answered (0 when the queue stayed empty).

        Containment contract: everything from batch-form to the host
        decision read runs under a per-batch guard — a failure there
        resolves this batch's futures with
        :class:`repro.serve.asa.ServeStepError` and returns; the table
        keeps its pre-dispatch state (the functional update commits only
        after the host read), and the loop lives on.  Only an exception
        *outside* the guard (e.g. an injected crash at the boundary)
        kills the loop — and then the crash path drains everything."""
        if self._chaos is not None:
            # boundary hook: bursts land in the queue (drained below, or
            # by the crash path), a crash raise escapes to _run
            self._chaos.on_batch_boundary(self)
        o = self._obs
        t0 = o.now()
        batch = self._drain(self.cfg.batch_wait_s
                            if wait_s is None else wait_s)
        if not batch:
            return 0
        # tenants with rows in THIS batch must survive pressure eviction:
        # a shed-then-readmit inside one batch would reuse a slot within
        # a single scatter
        protected = {req.tenant for req, _f in batch} \
            if self._pool is not None else frozenset()
        now_lease = time.monotonic() if self._pool is not None else 0.0
        slots = np.zeros(len(batch), np.int32)
        waits = np.zeros(len(batch), np.float32)
        has = np.zeros(len(batch), bool)
        live: list[tuple[int, Future, Request]] = []  # (row, future, req)
        for i, (req, fut) in enumerate(batch):
            slot = self._slot_of.get(req.tenant)
            if slot is None:
                try:
                    slot = self._admit(req.tenant, protected)
                except TableFullError as e:
                    fut.set_exception(e)
                    o.c_table_full.inc()
                    tf = o.now()
                    o.instant("table_full", tf, {"tenant": req.tenant})
                    o.resolve(req.rid, req.tenant, req.t_enqueue, tf,
                              error="table_full")
                    continue
            elif self._pool is not None:
                # serving traffic refreshes the lease: only tenants idle
                # a full TTL are sweep/LRU candidates
                lease = self._lease_of.get(req.tenant)
                if lease is not None:
                    self._pool.renew(
                        lease, now_lease + self.cfg.tenant_ttl_s)
            slots[i] = slot
            if req.observed_wait is not None:
                waits[i] = req.observed_wait
                has[i] = True
            self._requests_of[req.tenant] = \
                self._requests_of.get(req.tenant, 0) + 1
            live.append((i, fut, req))
        if not live:  # every request failed admission — nothing to serve
            return 0
        try:
            if self._chaos is not None:
                self._chaos.before_device_step(self._batches)
            t1 = o.now()
            q = serve_asa.QueryBatch(
                slot=jax.numpy.asarray(slots),
                observed_wait=jax.numpy.asarray(waits),
                has_obs=jax.numpy.asarray(has))
            # pad to the one compiled (batch_size,) shape; the mask
            # guards the pad rows (copies of query 0) from ever touching
            # the table
            qp, mask = pfleet.pad_batch(q, self.cfg.batch_size)
            t2 = o.now()
            new_table, dec = serve_asa.serve_step(self._table, qp, mask,
                                                  mesh=self._mesh)
            t3 = o.now()
            # ONE host-blocked device read for the whole decision batch —
            # the scatter-read leg of the request lifecycle
            lead, expected, entropy = serve_asa.decisions_to_host(dec)
        except Exception as e:
            # per-batch containment: this batch's futures fail typed,
            # the table keeps its pre-dispatch state, the loop survives
            err = serve_asa.ServeStepError(
                f"decision step failed at batch {self._batches}: {e!r}",
                batch=self._batches)
            err.__cause__ = e
            t_err = o.now()
            for _i, fut, req in live:
                fut.set_exception(err)
                o.resolve(req.rid, req.tenant, req.t_enqueue, t_err,
                          error="step_error")
            o.c_step_errors.inc()
            o.instant("step_error", t_err,
                      {"batch": self._batches, "error": repr(e)})
            return 0
        self._table = new_table   # commit only after the read succeeded
        t4 = o.now()
        # one resolve timestamp + one bulk resolve for the whole batch —
        # the requests leave together, and per-request observability
        # calls are measurable at full rate (the bench's overhead
        # budget pays for them)
        t_res = o.now()
        for i, fut, req in live:
            fut.set_result(Decision(req.tenant, float(lead[i]),
                                    float(expected[i]),
                                    float(entropy[i])))
        o.resolve_many([req for _i, _f, req in live], t_res)
        self._batches += 1
        self._last_batch_ts = time.monotonic()
        o.c_batches.inc()
        o.c_decisions.inc(len(live))
        o.c_padded.inc(self.cfg.batch_size - len(live))
        if o.spans:
            t5 = o.now()
            fill = len(live) / self.cfg.batch_size
            o.h_batch_fill.observe(fill)
            o.h_device_step.observe(t3 - t2)
            o.h_scatter_read.observe(t4 - t3)
            o.span("batch_form", t0, t1, {
                "batch": self._batches, "size": len(batch),
                "live": len(live), "batch_size": self.cfg.batch_size,
                "n_obs": int(has.sum()),
                "pad_fraction": 1.0 - fill,
                "deferred": len(self._deferred)})
            o.span("pad", t1, t2)
            o.span("device_step", t2, t3, {"async_dispatch": True})
            o.span("scatter_read", t3, t4, {"host_blocked": True})
            o.span("future_resolve", t4, t5, {"resolved": len(live)})
        if (self.cfg.checkpoint_every
                and self._batches % self.cfg.checkpoint_every == 0):
            # cadenced saves are contained: a failed snapshot (or a
            # previous async save surfacing its failure here) is counted
            # and serving continues — the on-disk latest stays the
            # previous good step.  The direct save_async() API still
            # raises (callers own their error handling).
            try:
                if self._chaos is not None:
                    self._chaos.on_checkpoint(self._batches)
                self.save_async()
            except Exception as e:
                o.c_ckpt_failures.inc()
                o.instant("checkpoint_failure", o.now(),
                          {"batch": self._batches, "error": repr(e)})
                h = self._ckpt_handle
                if h is not None and h.done():
                    # its failure surfaced here; don't re-raise it at
                    # stop()/next cadence
                    self._ckpt_handle = None
        return len(live)

    def _drain_all_pending_locked(self) -> list[tuple[Request, Future]]:
        """Pop every queued + deferred item (caller holds _ingress_lock)."""
        items: list[tuple[Request, Future]] = []
        while True:
            try:
                items.append(self._queue.get_nowait())
            except queue.Empty:
                break
        items.extend(self._deferred)
        self._deferred = deque()
        return items

    def _crash(self, exc: BaseException) -> None:
        """The loop thread died: fail everything pending with a typed
        error (no future may hang), mark the incarnation dead, and
        signal the supervisor."""
        o = self._obs
        with self._ingress_lock:
            self._crashed = exc
            pending = self._drain_all_pending_locked()
        t = o.now()
        for req, fut in pending:
            err = ServerCrashed(
                f"serve loop crashed before this request was served: "
                f"{exc!r}")
            err.__cause__ = exc
            fut.set_exception(err)
            o.resolve(req.rid, req.tenant, req.t_enqueue, t,
                      error="crashed")
        o.c_crashes.inc()
        o.g_loop_healthy.set(0.0)
        o.g_deferred.set(0)
        o.instant("crash", t, {"batch": self._batches,
                               "error": repr(exc),
                               "drained": len(pending)})
        self._crash_event.set()

    def _run(self) -> None:
        o = self._obs
        o.g_loop_healthy.set(1.0)
        self._last_batch_ts = time.monotonic()
        try:
            while not self._stop.is_set():
                if self.step_once() == 0:
                    # queue stayed empty for batch_wait_s: yield briefly
                    # so a stopped server exits promptly (sqswatcher's
                    # idle poll)
                    self._stop.wait(self.cfg.batch_wait_s)
            o.g_loop_healthy.set(0.0)
        except BaseException as e:
            self._crash(e)

    def start(self) -> None:
        """Run the serve loop in a daemon thread (plus the metrics
        endpoint when ``ServeConfig.metrics_port`` is set).  A stopped
        server restarts cleanly; a crashed one must be rebuilt
        (``ASAServer.restore`` / :class:`ServeSupervisor`)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self._crashed is not None:
            raise ServerCrashed(
                "cannot start a crashed server; restore a fresh one "
                "from its checkpoint") from self._crashed
        with self._ingress_lock:
            self._stopped = False
        if self.cfg.metrics_port is not None and self._http is None:
            self.serve_metrics_http(self.cfg.metrics_port)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="asa-serve-loop")
        self._thread.start()

    def stop(self) -> None:
        """Stop the loop and **drain-and-fail** everything still queued
        or deferred with :class:`ServerStopped` — no future ever hangs
        across a stop.  Idempotent: repeated calls are no-ops.  The
        server can ``start()`` again afterwards (state intact); while
        stopped, ``submit()`` raises immediately."""
        o = self._obs
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._stop.clear()
        with self._ingress_lock:
            self._stopped = True
            pending = self._drain_all_pending_locked()
        if pending:
            t = o.now()
            for req, fut in pending:
                fut.set_exception(ServerStopped(
                    "server stopped before this request was served"))
                o.resolve(req.rid, req.tenant, req.t_enqueue, t,
                          error="stopped")
            o.c_stop_drained.inc(len(pending))
            o.g_deferred.set(0)
        o.g_loop_healthy.set(0.0)
        self.stop_metrics_http()
        if self._ckpt_handle is not None:
            handle, self._ckpt_handle = self._ckpt_handle, None
            handle.result()

    # ------------------------------------------------------ metrics scrape
    def serve_metrics_http(self, port: int = 0,
                           host: str = "127.0.0.1") -> int:
        """Start the scrape endpoint on a stdlib ``ThreadingHTTPServer``
        daemon thread; returns the bound port (pass ``port=0`` for an
        ephemeral one).

        * ``GET /metrics`` — Prometheus text exposition of the registry;
        * ``GET /metrics.json`` — the registry snapshot as JSON;
        * ``GET /stats`` — the ``stats`` view (backward-compatible keys).

        Scrapes read live metric values metric-by-metric — a slow
        scraper never blocks the serve loop.  A scrape racing a shutdown
        answers 500 (the handler thread never dies on a socket error).
        """
        if self._http is not None:
            raise RuntimeError("metrics endpoint already running")
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
                try:
                    if self.path == "/metrics":
                        body = server._obs.registry.prometheus_text() \
                            .encode()
                        ctype = "text/plain; version=0.0.4; charset=utf-8"
                    elif self.path == "/metrics.json":
                        body = json.dumps(
                            server._obs.registry.snapshot()).encode()
                        ctype = "application/json"
                    elif self.path == "/stats":
                        body = json.dumps(server.stats).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404)
                        return
                except Exception:
                    # snapshot raced a shutdown/teardown: a well-formed
                    # 500 beats an exception unwinding the handler thread
                    try:
                        self.send_error(500)
                    except OSError:
                        pass
                    return
                try:
                    self.send_response(200)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                except OSError:
                    pass  # client hung up mid-write; nothing to answer

            def log_message(self, *args) -> None:  # quiet by design
                pass

        self._http = ThreadingHTTPServer((host, port), Handler)
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, daemon=True,
            name="asa-serve-metrics")
        self._http_thread.start()
        return self._http.server_address[1]

    def stop_metrics_http(self) -> None:
        """Stop the scrape endpoint; idempotent (extra calls no-op)."""
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
            self._http = None
        if self._http_thread is not None:
            self._http_thread.join()
            self._http_thread = None

    # --------------------------------------------------------- durability
    def _state_tree(self) -> dict:
        # the full durable state: posteriors AND the host bookkeeping
        # that shapes future admissions (the dirty mask and the
        # admissions counter that salts reset keys) — so a restored
        # server admits new tenants with the exact keys the
        # uninterrupted one would have used
        dirty = np.zeros(self.cfg.n_slots, bool)
        if self._dirty:
            dirty[list(self._dirty)] = True
        return {"table": self._table, "tenant_ids": self._tenant_ids,
                "admissions": np.int32(self._admissions), "dirty": dirty}

    def save(self, step: Optional[int] = None) -> Path:
        """Synchronous snapshot through the checkpoint codec."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        return checkpoint.save(self._state_tree(), self.cfg.checkpoint_dir,
                               self._batches if step is None else step)

    def save_async(self, step: Optional[int] = None) -> checkpoint.AsyncSave:
        """Background snapshot; a previously-failed save raises HERE (the
        handle's result() re-raises), so cadenced saves can't fail
        silently batch after batch.  The time blocked collecting the
        previous handle is the checkpoint-cadence stall the observability
        layer reports (counter + ``checkpoint_stall`` span)."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        o = self._obs
        if self._ckpt_handle is not None:
            ts = time.perf_counter()
            self._ckpt_handle.result()
            stall = time.perf_counter() - ts
            o.c_ckpt_stall_s.inc(stall)
            if o.spans:
                o.span("checkpoint_stall", ts, ts + stall,
                       {"batch": self._batches})
        o.c_checkpoints.inc()
        self._ckpt_handle = checkpoint.save_async(
            self._state_tree(), self.cfg.checkpoint_dir,
            self._batches if step is None else step)
        return self._ckpt_handle

    @classmethod
    def restore(cls, cfg: ServeConfig, step: Optional[int] = None,
                mesh=None, obs: Optional[ServeObs] = None, chaos=None,
                verified: bool = False) -> "ASAServer":
        """Resume a server from its checkpoint: posteriors (PRNG keys
        included) and the tenant map come back exactly, so the restarted
        server's decisions are bitwise those of the uninterrupted one.
        ``verified=True`` picks the newest checkpoint that passes
        integrity verification (a corrupted latest degrades to the
        previous good step).  Registry counters restart at zero — they
        describe the process, not the estimator — unless a shared
        ``obs`` carries them across incarnations (the supervisor does).
        """
        assert cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        if step is None:
            step = checkpoint.latest_step(cfg.checkpoint_dir,
                                          verified=verified)
            if step is None:
                raise FileNotFoundError(
                    f"no {'verified ' if verified else ''}checkpoint "
                    f"under {cfg.checkpoint_dir}")
        server = cls(cfg, mesh=mesh, obs=obs, chaos=chaos)
        tree = checkpoint.restore(server._state_tree(),
                                  cfg.checkpoint_dir, step)
        server._table = tree["table"]
        # np.array (copy): asarray on a jax array yields a read-only view
        server._tenant_ids = np.array(tree["tenant_ids"], np.int32)
        server._slot_of = {int(t): s
                           for s, t in enumerate(server._tenant_ids)
                           if t >= 0}
        occupied = set(server._slot_of.values())
        server._free = deque(s for s in range(cfg.n_slots)
                             if s not in occupied)
        # the dirty mask and admissions salt come back exactly, so a
        # post-restart admission resets (or not) with the very key the
        # uninterrupted server would have used
        dirty = np.asarray(tree["dirty"])
        server._dirty = {s for s in range(cfg.n_slots) if dirty[s]}
        server._admissions = int(tree["admissions"])
        server._batches = step
        if server._pool is not None:
            # leases are process state, not estimator state: every
            # restored tenant starts one fresh TTL ahead
            now = time.monotonic()
            for tenant in server._slot_of:
                server._grant_lease(tenant, now)
        server._obs.g_tenants.set(len(server._slot_of))
        server._obs.g_free_slots.set(len(server._free))
        return server

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Registry view: the PR-7 keys keep their exact meaning
        (``batches`` counts this process's dispatched steps — a restored
        server resumes at its checkpoint step as before); the new keys
        surface the registry counters, including the lifetime request
        totals of evicted tenants snapshotted at evict time and the
        fault-tolerance counters (sheds, step errors, crashes,
        restarts, lease evictions)."""
        o = self._obs
        return {
            "batches": self._batches,
            "decisions": int(o.c_decisions.value),
            "tenants": self.n_tenants,
            "n_slots": self.cfg.n_slots,
            "deferred": len(self._deferred),
            "requests": int(o.c_requests.value),
            "deferrals": int(o.c_deferrals.value),
            "failed": int(o.c_failed.value),
            "table_full": int(o.c_table_full.value),
            "admissions_live": int(o.c_admissions.value),
            "evicted_tenants": int(o.c_evictions.value),
            "evicted_requests": int(o.c_evicted_requests.value),
            "shed": int(o.c_shed.value),
            "step_errors": int(o.c_step_errors.value),
            "crashes": int(o.c_crashes.value),
            "restarts": int(o.c_restarts.value),
            "lease_evictions": int(o.c_lease_evictions.value),
        }


class ServeSupervisor:
    """Crash supervision for one logical ASA server.

    Owns the server's lifecycle the way an init system would: a watch
    thread waits on the incarnation's crash signal; on crash it restores
    a fresh :class:`ASAServer` from the newest **verified** checkpoint
    (``latest_step(verified=True)`` — a torn/corrupted latest degrades
    to the previous good one) and starts it.  Nothing is replayed: the
    crash path already failed every pending future with
    :class:`ServerCrashed`, so clients resubmit, and the restored
    posteriors answer bitwise what the uninterrupted server would have
    (the crash-recovery extension of the restart contract, pinned by
    tests/test_serve_chaos.py).

    One :class:`ServeObs` is shared across incarnations, so counters,
    the scrape endpoint's view, and ``asa_serve_restarts_total`` all
    describe the logical service, not one loop thread.  ``submit()``
    retries across the swap window (bounded), so callers race restarts
    safely.
    """

    def __init__(self, cfg: ServeConfig, mesh=None, chaos=None,
                 max_restarts: int = 10,
                 obs: Optional[ServeObs] = None):
        self.cfg = cfg
        self._mesh = mesh
        self._chaos = chaos
        self.max_restarts = max_restarts
        self.obs = obs if obs is not None else ServeObs(spans=cfg.obs_spans)
        self.restarts = 0
        self._closing = False
        self._watch: Optional[threading.Thread] = None
        self.server = ASAServer(cfg, mesh=mesh, obs=self.obs, chaos=chaos)

    def start(self) -> None:
        self.server.start()
        self._watch = threading.Thread(target=self._watch_loop,
                                       daemon=True,
                                       name="asa-serve-supervisor")
        self._watch.start()

    def _watch_loop(self) -> None:
        while not self._closing:
            srv = self.server
            if not srv._crash_event.wait(timeout=0.05):
                continue
            if self._closing or self.restarts >= self.max_restarts:
                return
            self._restart(srv)

    def _restart(self, crashed: ASAServer) -> None:
        crashed.stop_metrics_http()
        if crashed._ckpt_handle is not None:
            try:
                crashed._ckpt_handle.result()
            except Exception:
                self.obs.c_ckpt_failures.inc()
            crashed._ckpt_handle = None
        step = None
        if self.cfg.checkpoint_dir:
            step = checkpoint.latest_step(self.cfg.checkpoint_dir,
                                          verified=True)
        if step is not None:
            fresh = ASAServer.restore(self.cfg, step=step,
                                      mesh=self._mesh, obs=self.obs,
                                      chaos=self._chaos)
        else:
            # nothing durable yet: restart empty (clients re-admit)
            fresh = ASAServer(self.cfg, mesh=self._mesh, obs=self.obs,
                              chaos=self._chaos)
        fresh.start()
        self.server = fresh
        self.restarts += 1
        self.obs.c_restarts.inc()
        self.obs.instant("restart", self.obs.now(),
                         {"restarts": self.restarts, "from_step": step})

    def submit(self, tenant: int,
               observed_wait: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Future:
        """Submit against the current incarnation, riding out a restart
        swap: a :class:`ServerCrashed` race waits for the replacement
        (bounded) and retries once per incarnation."""
        deadline = time.monotonic() + 30.0
        while True:
            srv = self.server
            try:
                return srv.submit(tenant, observed_wait,
                                  deadline_s=deadline_s)
            except ServerCrashed:
                while (self.server is srv
                       and time.monotonic() < deadline
                       and not self._closing):
                    time.sleep(0.005)
                if self.server is srv:
                    raise

    def stop(self) -> None:
        """Stop the watch thread first (no restart may race the stop),
        then the current incarnation (drain-and-fail semantics)."""
        self._closing = True
        if self._watch is not None:
            self._watch.join()
            self._watch = None
        self.server.stop()

    @property
    def stats(self) -> dict:
        s = self.server.stats
        s["restarts"] = self.restarts
        return s


def estimate_lead(state: core_asa.ASAState, bins) -> jax.Array:
    """Convenience: the submit-lead-time a single estimator answers
    (MAP wait — what ``DecisionBatch.lead_s`` reports per tenant)."""
    return core_asa.map_wait(state, bins)
