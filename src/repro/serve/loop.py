"""Event-loop shell around the jitted ASA decision core.

Architecture follows the AWS ParallelCluster daemon split the ROADMAP
names (sqswatcher/nodewatcher: a thin event-queue-driven shell making
scale decisions around a core): pure stdlib threading — producers
``submit()`` requests into a ``queue.Queue``; the serve loop drains up
to ``batch_size`` of them, pads the batch with
``parallel.fleet.pad_batch``, dispatches ONE jitted
``serve.asa.serve_step`` (vmap, or shard_map when ``n_shards`` is set),
and resolves each request's ``concurrent.futures.Future`` with its
:class:`Decision`.

Host-side responsibilities (everything the jitted core must not know):

* **tenant admission** — tenant ids map to fixed table slots; a new
  tenant takes a free slot (fresh slots were initialised at table build;
  reused slots are reset through ``serve.asa.reset_slot`` with a fresh
  fold_in key).  A full table raises :class:`TableFullError` into the
  request's future, never into the loop.
* **observation dedup** — the decision core requires at most one
  observation per slot per batch (the scatter must be well-defined).
  The batcher defers a tenant's second same-batch observation — and
  every later request of that tenant, preserving per-tenant order — to
  the next batch.
* **checkpoint cadence** — every ``checkpoint_every`` batches the server
  snapshots ``{table, tenant_ids, admissions, dirty}`` through
  ``runtime.checkpoint``
  (``save_async``; the previous handle's ``result()`` is collected first
  so a failed background save raises in the serve loop, not silently).
  ``ASAServer.restore`` resumes a server whose posteriors — PRNG keys
  included — are bitwise what the saved server held, so restarted
  decisions are bit-identical (pinned by tests/test_serve.py).
* **observability** — every server carries a
  :class:`repro.obs.serve_obs.ServeObs`: an always-on
  ``obs.registry`` metric set (``stats`` is a view over it; the
  Prometheus/JSON scrape endpoint below exposes it live) plus
  request-lifecycle span recording that is **off by default**
  (``ServeConfig.obs_spans``) — with spans off no timestamps are taken
  and the decision path is bit-identical to the uninstrumented server.
  ``serve_metrics_http()`` serves ``GET /metrics`` (Prometheus text),
  ``/metrics.json`` (registry snapshot) and ``/stats`` on a stdlib
  ``ThreadingHTTPServer`` — no new dependencies.

The registry is deliberately **not** part of the checkpoint: counters
describe this process's lifetime, not the estimator state; a restored
server starts its counters at zero while answering bitwise-identically.
"""

from __future__ import annotations

import json
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.core import asa as core_asa
from repro.obs.serve_obs import ServeObs
from repro.parallel import fleet as pfleet
from repro.runtime import checkpoint
from repro.serve import asa as serve_asa


class TableFullError(RuntimeError):
    """Every tenant slot is occupied; evict a tenant first."""


@dataclass(frozen=True)
class ServeConfig:
    """Static server parameters (one compiled step per config)."""

    n_slots: int = 1024        # fixed tenant-table capacity
    m: int = 53                # wait-bin count (paper §4.3)
    batch_size: int = 256      # queries per jitted step (the padded shape)
    n_shards: Optional[int] = None  # shard_map the query axis over N devices
    batch_wait_s: float = 0.002     # max idle wait for the first request
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0  # batches between async snapshots (0 = off)
    seed: int = 0
    obs_spans: bool = False    # record request-lifecycle spans (wall-clock)
    metrics_port: Optional[int] = None  # start() scrapes here (0 = any)

    def __post_init__(self) -> None:
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")
        if self.n_shards is not None and \
                self.batch_size % self.n_shards != 0:
            raise ValueError(
                f"batch_size {self.batch_size} not divisible by n_shards "
                f"{self.n_shards}: the padded batch must split evenly "
                "over the mesh")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError("checkpoint_every set without checkpoint_dir")


@dataclass
class Request:
    """One tenant query: an optional observed stage wait to learn from,
    and (always) the submit-lead-time decision for the next stage.

    ``rid``/``t_enqueue`` are observability bookkeeping stamped by
    ``submit()`` when span recording is on (-1/0.0 otherwise)."""

    tenant: int
    observed_wait: Optional[float] = None
    rid: int = -1
    t_enqueue: float = 0.0


@dataclass
class Decision:
    """The answer: submit the next stage ``lead_s`` seconds before the
    current stage's expected end (MAP wait); ``expected_s``/``entropy``
    report the posterior mean and how much the estimator still hedges."""

    tenant: int
    lead_s: float
    expected_s: float
    entropy: float


class ASAServer:
    """Batched ASA decision service over a fixed-slot tenant table."""

    def __init__(self, cfg: ServeConfig, mesh=None,
                 obs: Optional[ServeObs] = None):
        self.cfg = cfg
        if mesh is None and cfg.n_shards is not None:
            from repro.launch.mesh import make_scenarios_mesh
            mesh = make_scenarios_mesh(cfg.n_shards)
        self._mesh = mesh
        self._obs = obs if obs is not None else \
            ServeObs(spans=cfg.obs_spans)
        self._table = serve_asa.init_table(cfg.n_slots, cfg.m, cfg.seed)
        # host-side tenant bookkeeping: the (n_slots,) id array is part of
        # the checkpointed state; the dict/free-list are derived views.
        # int32 on purpose: the checkpoint codec restores through jnp,
        # which is 32-bit without x64 — tenant ids must fit i32
        self._tenant_ids = np.full(cfg.n_slots, -1, np.int32)
        self._slot_of: dict[int, int] = {}
        self._free: deque[int] = deque(range(cfg.n_slots))
        self._dirty: set[int] = set()   # freed slots needing a reset
        self._admissions = 0            # salts reset keys
        self._requests_of: dict[int, int] = {}  # per-tenant lifetime count
        self._queue: "queue.Queue[tuple[Request, Future]]" = queue.Queue()
        self._deferred: deque[tuple[Request, Future]] = deque()
        self._batches = 0
        self._ckpt_handle: Optional[checkpoint.AsyncSave] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._http: Optional[ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        self._obs.g_free_slots.set(len(self._free))

    @property
    def obs(self) -> ServeObs:
        """The server's registry + span recorder (always present)."""
        return self._obs

    # ------------------------------------------------------------ tenants
    @property
    def n_tenants(self) -> int:
        return len(self._slot_of)

    def _admit(self, tenant: int) -> int:
        if not self._free:
            raise TableFullError(
                f"all {self.cfg.n_slots} tenant slots occupied")
        slot = self._free.popleft()
        if slot in self._dirty:
            # slot reuse: back to the uniform prior with a fresh key
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.cfg.seed ^ 0x5A5A5A5A),
                self._admissions)
            self._table = serve_asa.reset_slot(self._table, slot, key)
            self._dirty.discard(slot)
        self._admissions += 1
        self._slot_of[tenant] = slot
        self._tenant_ids[slot] = tenant
        o = self._obs
        o.c_admissions.inc()
        o.g_tenants.set(len(self._slot_of))
        o.g_free_slots.set(len(self._free))
        o.instant("admit", o.now(), {"tenant": tenant, "slot": slot})
        return slot

    def evict(self, tenant: int) -> None:
        """Free a tenant's slot (its posterior resets on slot reuse).

        The tenant's lifetime request total is snapshotted into the
        registry (``asa_serve_evicted_requests_total``) at this moment,
        so fleet accounting survives the eviction — ``stats`` no longer
        silently loses an evicted tenant's counts."""
        slot = self._slot_of.pop(tenant)
        self._tenant_ids[slot] = -1
        self._dirty.add(slot)
        self._free.append(slot)
        lifetime = self._requests_of.pop(tenant, 0)
        o = self._obs
        o.c_evictions.inc()
        o.c_evicted_requests.inc(lifetime)
        o.g_tenants.set(len(self._slot_of))
        o.g_free_slots.set(len(self._free))
        o.instant("evict", o.now(),
                  {"tenant": tenant, "slot": slot, "requests": lifetime})

    # ------------------------------------------------------------ serving
    def submit(self, tenant: int,
               observed_wait: Optional[float] = None) -> Future:
        """Enqueue one request; the future resolves to a Decision."""
        fut: Future = Future()
        req = Request(tenant, observed_wait)
        o = self._obs
        o.c_requests.inc()
        o.g_inflight.inc()
        if observed_wait is not None:
            o.c_observations.inc()
        if o.spans:
            req.rid = o.next_rid()
            req.t_enqueue = time.perf_counter()
            o.enqueue(req.rid, tenant, req.t_enqueue)
        self._queue.put((req, fut))
        return fut

    def _drain(self, wait_s: float) -> list[tuple[Request, Future]]:
        """Pull queued requests into the deferred deque, then pick the
        next batch in order, deferring any tenant whose second same-batch
        observation would break the unique-scatter invariant."""
        pending = self._deferred
        timeout = wait_s if not pending else 0.0
        while True:
            try:
                item = (self._queue.get(timeout=timeout)
                        if timeout > 0 else self._queue.get_nowait())
            except queue.Empty:
                break
            pending.append(item)
            timeout = 0.0
        batch: list[tuple[Request, Future]] = []
        held: deque[tuple[Request, Future]] = deque()
        obs_seen: set[int] = set()
        blocked: set[int] = set()
        o = self._obs
        t_d = o.now()  # one defer timestamp per drain: deferral events
        #                are batch-granular, a clock read each is not free
        while pending and len(batch) < self.cfg.batch_size:
            req, fut = pending.popleft()
            if req.tenant in blocked:
                o.defer(req.rid, req.tenant, t_d)
                held.append((req, fut))
                continue
            if req.observed_wait is not None:
                if req.tenant in obs_seen:
                    # second observation for this slot: defer it (and all
                    # later requests of this tenant — order preserved)
                    blocked.add(req.tenant)
                    o.defer(req.rid, req.tenant, t_d)
                    held.append((req, fut))
                    continue
                obs_seen.add(req.tenant)
            batch.append((req, fut))
        held.extend(pending)
        self._deferred = held
        o.g_deferred.set(len(held))
        return batch

    def step_once(self, wait_s: Optional[float] = None) -> int:
        """Drain + dispatch one batch; returns the number of requests
        answered (0 when the queue stayed empty)."""
        o = self._obs
        t0 = o.now()
        batch = self._drain(self.cfg.batch_wait_s
                            if wait_s is None else wait_s)
        if not batch:
            return 0
        slots = np.zeros(len(batch), np.int32)
        waits = np.zeros(len(batch), np.float32)
        has = np.zeros(len(batch), bool)
        live: list[tuple[int, Future, Request]] = []  # (row, future, req)
        for i, (req, fut) in enumerate(batch):
            slot = self._slot_of.get(req.tenant)
            if slot is None:
                try:
                    slot = self._admit(req.tenant)
                except TableFullError as e:
                    fut.set_exception(e)
                    o.c_table_full.inc()
                    tf = o.now()
                    o.instant("table_full", tf, {"tenant": req.tenant})
                    o.resolve(req.rid, req.tenant, req.t_enqueue, tf,
                              error="table_full")
                    continue
            slots[i] = slot
            if req.observed_wait is not None:
                waits[i] = req.observed_wait
                has[i] = True
            self._requests_of[req.tenant] = \
                self._requests_of.get(req.tenant, 0) + 1
            live.append((i, fut, req))
        if not live:  # every request failed admission — nothing to serve
            return 0
        t1 = o.now()
        q = serve_asa.QueryBatch(
            slot=jax.numpy.asarray(slots),
            observed_wait=jax.numpy.asarray(waits),
            has_obs=jax.numpy.asarray(has))
        # pad to the one compiled (batch_size,) shape; the mask guards the
        # pad rows (copies of query 0) from ever touching the table
        qp, mask = pfleet.pad_batch(q, self.cfg.batch_size)
        t2 = o.now()
        self._table, dec = serve_asa.serve_step(self._table, qp, mask,
                                                mesh=self._mesh)
        t3 = o.now()
        # ONE host-blocked device read for the whole decision batch —
        # the scatter-read leg of the request lifecycle
        lead, expected, entropy = serve_asa.decisions_to_host(dec)
        t4 = o.now()
        # one resolve timestamp + one bulk resolve for the whole batch —
        # the requests leave together, and per-request observability
        # calls are measurable at full rate (the bench's overhead
        # budget pays for them)
        t_res = o.now()
        for i, fut, req in live:
            fut.set_result(Decision(req.tenant, float(lead[i]),
                                    float(expected[i]),
                                    float(entropy[i])))
        o.resolve_many([req for _i, _f, req in live], t_res)
        self._batches += 1
        o.c_batches.inc()
        o.c_decisions.inc(len(live))
        o.c_padded.inc(self.cfg.batch_size - len(live))
        if o.spans:
            t5 = o.now()
            fill = len(live) / self.cfg.batch_size
            o.h_batch_fill.observe(fill)
            o.h_device_step.observe(t3 - t2)
            o.h_scatter_read.observe(t4 - t3)
            o.span("batch_form", t0, t1, {
                "batch": self._batches, "size": len(batch),
                "live": len(live), "batch_size": self.cfg.batch_size,
                "n_obs": int(has.sum()),
                "pad_fraction": 1.0 - fill,
                "deferred": len(self._deferred)})
            o.span("pad", t1, t2)
            o.span("device_step", t2, t3, {"async_dispatch": True})
            o.span("scatter_read", t3, t4, {"host_blocked": True})
            o.span("future_resolve", t4, t5, {"resolved": len(live)})
        if (self.cfg.checkpoint_every
                and self._batches % self.cfg.checkpoint_every == 0):
            self.save_async()
        return len(live)

    def _run(self) -> None:
        while not self._stop.is_set():
            if self.step_once() == 0:
                # queue stayed empty for batch_wait_s: yield briefly so a
                # stopped server exits promptly (sqswatcher's idle poll)
                self._stop.wait(self.cfg.batch_wait_s)

    def start(self) -> None:
        """Run the serve loop in a daemon thread (plus the metrics
        endpoint when ``ServeConfig.metrics_port`` is set)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.cfg.metrics_port is not None and self._http is None:
            self.serve_metrics_http(self.cfg.metrics_port)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="asa-serve-loop")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._stop.clear()
        self.stop_metrics_http()
        if self._ckpt_handle is not None:
            self._ckpt_handle.result()
            self._ckpt_handle = None

    # ------------------------------------------------------ metrics scrape
    def serve_metrics_http(self, port: int = 0,
                           host: str = "127.0.0.1") -> int:
        """Start the scrape endpoint on a stdlib ``ThreadingHTTPServer``
        daemon thread; returns the bound port (pass ``port=0`` for an
        ephemeral one).

        * ``GET /metrics`` — Prometheus text exposition of the registry;
        * ``GET /metrics.json`` — the registry snapshot as JSON;
        * ``GET /stats`` — the ``stats`` view (backward-compatible keys).

        Scrapes read live metric values metric-by-metric — a slow
        scraper never blocks the serve loop.
        """
        if self._http is not None:
            raise RuntimeError("metrics endpoint already running")
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
                if self.path == "/metrics":
                    body = server._obs.registry.prometheus_text().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/metrics.json":
                    body = json.dumps(
                        server._obs.registry.snapshot()).encode()
                    ctype = "application/json"
                elif self.path == "/stats":
                    body = json.dumps(server.stats).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:  # quiet by design
                pass

        self._http = ThreadingHTTPServer((host, port), Handler)
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, daemon=True,
            name="asa-serve-metrics")
        self._http_thread.start()
        return self._http.server_address[1]

    def stop_metrics_http(self) -> None:
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
            self._http = None
        if self._http_thread is not None:
            self._http_thread.join()
            self._http_thread = None

    # --------------------------------------------------------- durability
    def _state_tree(self) -> dict:
        # the full durable state: posteriors AND the host bookkeeping
        # that shapes future admissions (the dirty mask and the
        # admissions counter that salts reset keys) — so a restored
        # server admits new tenants with the exact keys the
        # uninterrupted one would have used
        dirty = np.zeros(self.cfg.n_slots, bool)
        if self._dirty:
            dirty[list(self._dirty)] = True
        return {"table": self._table, "tenant_ids": self._tenant_ids,
                "admissions": np.int32(self._admissions), "dirty": dirty}

    def save(self, step: Optional[int] = None) -> Path:
        """Synchronous snapshot through the checkpoint codec."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        return checkpoint.save(self._state_tree(), self.cfg.checkpoint_dir,
                               self._batches if step is None else step)

    def save_async(self, step: Optional[int] = None) -> checkpoint.AsyncSave:
        """Background snapshot; a previously-failed save raises HERE (the
        handle's result() re-raises), so cadenced saves can't fail
        silently batch after batch.  The time blocked collecting the
        previous handle is the checkpoint-cadence stall the observability
        layer reports (counter + ``checkpoint_stall`` span)."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        o = self._obs
        if self._ckpt_handle is not None:
            ts = time.perf_counter()
            self._ckpt_handle.result()
            stall = time.perf_counter() - ts
            o.c_ckpt_stall_s.inc(stall)
            if o.spans:
                o.span("checkpoint_stall", ts, ts + stall,
                       {"batch": self._batches})
        o.c_checkpoints.inc()
        self._ckpt_handle = checkpoint.save_async(
            self._state_tree(), self.cfg.checkpoint_dir,
            self._batches if step is None else step)
        return self._ckpt_handle

    @classmethod
    def restore(cls, cfg: ServeConfig, step: Optional[int] = None,
                mesh=None) -> "ASAServer":
        """Resume a server from its checkpoint: posteriors (PRNG keys
        included) and the tenant map come back exactly, so the restarted
        server's decisions are bitwise those of the uninterrupted one.
        Registry counters restart at zero — they describe the process,
        not the estimator."""
        assert cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        if step is None:
            step = checkpoint.latest_step(cfg.checkpoint_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint under {cfg.checkpoint_dir}")
        server = cls(cfg, mesh=mesh)
        tree = checkpoint.restore(server._state_tree(),
                                  cfg.checkpoint_dir, step)
        server._table = tree["table"]
        # np.array (copy): asarray on a jax array yields a read-only view
        server._tenant_ids = np.array(tree["tenant_ids"], np.int32)
        server._slot_of = {int(t): s
                           for s, t in enumerate(server._tenant_ids)
                           if t >= 0}
        occupied = set(server._slot_of.values())
        server._free = deque(s for s in range(cfg.n_slots)
                             if s not in occupied)
        # the dirty mask and admissions salt come back exactly, so a
        # post-restart admission resets (or not) with the very key the
        # uninterrupted server would have used
        dirty = np.asarray(tree["dirty"])
        server._dirty = {s for s in range(cfg.n_slots) if dirty[s]}
        server._admissions = int(tree["admissions"])
        server._batches = step
        server._obs.g_tenants.set(len(server._slot_of))
        server._obs.g_free_slots.set(len(server._free))
        return server

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Registry view: the PR-7 keys keep their exact meaning
        (``batches`` counts this process's dispatched steps — a restored
        server resumes at its checkpoint step as before); the new keys
        surface the registry counters, including the lifetime request
        totals of evicted tenants snapshotted at evict time."""
        o = self._obs
        return {
            "batches": self._batches,
            "decisions": int(o.c_decisions.value),
            "tenants": self.n_tenants,
            "n_slots": self.cfg.n_slots,
            "deferred": len(self._deferred),
            "requests": int(o.c_requests.value),
            "deferrals": int(o.c_deferrals.value),
            "failed": int(o.c_failed.value),
            "table_full": int(o.c_table_full.value),
            "admissions_live": int(o.c_admissions.value),
            "evicted_tenants": int(o.c_evictions.value),
            "evicted_requests": int(o.c_evicted_requests.value),
        }


def estimate_lead(state: core_asa.ASAState, bins) -> jax.Array:
    """Convenience: the submit-lead-time a single estimator answers
    (MAP wait — what ``DecisionBatch.lead_s`` reports per tenant)."""
    return core_asa.map_wait(state, bins)
