"""Event-loop shell around the jitted ASA decision core.

Architecture follows the AWS ParallelCluster daemon split the ROADMAP
names (sqswatcher/nodewatcher: a thin event-queue-driven shell making
scale decisions around a core): pure stdlib threading — producers
``submit()`` requests into a ``queue.Queue``; the serve loop drains up
to ``batch_size`` of them, pads the batch with
``parallel.fleet.pad_batch``, dispatches ONE jitted
``serve.asa.serve_step`` (vmap, or shard_map when ``n_shards`` is set),
and resolves each request's ``concurrent.futures.Future`` with its
:class:`Decision`.

Host-side responsibilities (everything the jitted core must not know):

* **tenant admission** — tenant ids map to fixed table slots; a new
  tenant takes a free slot (fresh slots were initialised at table build;
  reused slots are reset through ``serve.asa.reset_slot`` with a fresh
  fold_in key).  A full table raises :class:`TableFullError` into the
  request's future, never into the loop.
* **observation dedup** — the decision core requires at most one
  observation per slot per batch (the scatter must be well-defined).
  The batcher defers a tenant's second same-batch observation — and
  every later request of that tenant, preserving per-tenant order — to
  the next batch.
* **checkpoint cadence** — every ``checkpoint_every`` batches the server
  snapshots ``{table, tenant_ids, admissions, dirty}`` through
  ``runtime.checkpoint``
  (``save_async``; the previous handle's ``result()`` is collected first
  so a failed background save raises in the serve loop, not silently).
  ``ASAServer.restore`` resumes a server whose posteriors — PRNG keys
  included — are bitwise what the saved server held, so restarted
  decisions are bit-identical (pinned by tests/test_serve.py).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.core import asa as core_asa
from repro.parallel import fleet as pfleet
from repro.runtime import checkpoint
from repro.serve import asa as serve_asa


class TableFullError(RuntimeError):
    """Every tenant slot is occupied; evict a tenant first."""


@dataclass(frozen=True)
class ServeConfig:
    """Static server parameters (one compiled step per config)."""

    n_slots: int = 1024        # fixed tenant-table capacity
    m: int = 53                # wait-bin count (paper §4.3)
    batch_size: int = 256      # queries per jitted step (the padded shape)
    n_shards: Optional[int] = None  # shard_map the query axis over N devices
    batch_wait_s: float = 0.002     # max idle wait for the first request
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0  # batches between async snapshots (0 = off)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")
        if self.n_shards is not None and \
                self.batch_size % self.n_shards != 0:
            raise ValueError(
                f"batch_size {self.batch_size} not divisible by n_shards "
                f"{self.n_shards}: the padded batch must split evenly "
                "over the mesh")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError("checkpoint_every set without checkpoint_dir")


@dataclass
class Request:
    """One tenant query: an optional observed stage wait to learn from,
    and (always) the submit-lead-time decision for the next stage."""

    tenant: int
    observed_wait: Optional[float] = None


@dataclass
class Decision:
    """The answer: submit the next stage ``lead_s`` seconds before the
    current stage's expected end (MAP wait); ``expected_s``/``entropy``
    report the posterior mean and how much the estimator still hedges."""

    tenant: int
    lead_s: float
    expected_s: float
    entropy: float


class ASAServer:
    """Batched ASA decision service over a fixed-slot tenant table."""

    def __init__(self, cfg: ServeConfig, mesh=None):
        self.cfg = cfg
        if mesh is None and cfg.n_shards is not None:
            from repro.launch.mesh import make_scenarios_mesh
            mesh = make_scenarios_mesh(cfg.n_shards)
        self._mesh = mesh
        self._table = serve_asa.init_table(cfg.n_slots, cfg.m, cfg.seed)
        # host-side tenant bookkeeping: the (n_slots,) id array is part of
        # the checkpointed state; the dict/free-list are derived views.
        # int32 on purpose: the checkpoint codec restores through jnp,
        # which is 32-bit without x64 — tenant ids must fit i32
        self._tenant_ids = np.full(cfg.n_slots, -1, np.int32)
        self._slot_of: dict[int, int] = {}
        self._free: deque[int] = deque(range(cfg.n_slots))
        self._dirty: set[int] = set()   # freed slots needing a reset
        self._admissions = 0            # salts reset keys
        self._queue: "queue.Queue[tuple[Request, Future]]" = queue.Queue()
        self._deferred: deque[tuple[Request, Future]] = deque()
        self._batches = 0
        self._decisions = 0
        self._ckpt_handle: Optional[checkpoint.AsyncSave] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ tenants
    @property
    def n_tenants(self) -> int:
        return len(self._slot_of)

    def _admit(self, tenant: int) -> int:
        if not self._free:
            raise TableFullError(
                f"all {self.cfg.n_slots} tenant slots occupied")
        slot = self._free.popleft()
        if slot in self._dirty:
            # slot reuse: back to the uniform prior with a fresh key
            key = jax.random.fold_in(
                jax.random.PRNGKey(self.cfg.seed ^ 0x5A5A5A5A),
                self._admissions)
            self._table = serve_asa.reset_slot(self._table, slot, key)
            self._dirty.discard(slot)
        self._admissions += 1
        self._slot_of[tenant] = slot
        self._tenant_ids[slot] = tenant
        return slot

    def evict(self, tenant: int) -> None:
        """Free a tenant's slot (its posterior resets on slot reuse)."""
        slot = self._slot_of.pop(tenant)
        self._tenant_ids[slot] = -1
        self._dirty.add(slot)
        self._free.append(slot)

    # ------------------------------------------------------------ serving
    def submit(self, tenant: int,
               observed_wait: Optional[float] = None) -> Future:
        """Enqueue one request; the future resolves to a Decision."""
        fut: Future = Future()
        self._queue.put((Request(tenant, observed_wait), fut))
        return fut

    def _drain(self, wait_s: float) -> list[tuple[Request, Future]]:
        """Pull queued requests into the deferred deque, then pick the
        next batch in order, deferring any tenant whose second same-batch
        observation would break the unique-scatter invariant."""
        pending = self._deferred
        timeout = wait_s if not pending else 0.0
        while True:
            try:
                item = (self._queue.get(timeout=timeout)
                        if timeout > 0 else self._queue.get_nowait())
            except queue.Empty:
                break
            pending.append(item)
            timeout = 0.0
        batch: list[tuple[Request, Future]] = []
        held: deque[tuple[Request, Future]] = deque()
        obs_seen: set[int] = set()
        blocked: set[int] = set()
        while pending and len(batch) < self.cfg.batch_size:
            req, fut = pending.popleft()
            if req.tenant in blocked:
                held.append((req, fut))
                continue
            if req.observed_wait is not None:
                if req.tenant in obs_seen:
                    # second observation for this slot: defer it (and all
                    # later requests of this tenant — order preserved)
                    blocked.add(req.tenant)
                    held.append((req, fut))
                    continue
                obs_seen.add(req.tenant)
            batch.append((req, fut))
        held.extend(pending)
        self._deferred = held
        return batch

    def step_once(self, wait_s: Optional[float] = None) -> int:
        """Drain + dispatch one batch; returns the number of requests
        answered (0 when the queue stayed empty)."""
        batch = self._drain(self.cfg.batch_wait_s
                            if wait_s is None else wait_s)
        if not batch:
            return 0
        slots = np.zeros(len(batch), np.int32)
        waits = np.zeros(len(batch), np.float32)
        has = np.zeros(len(batch), bool)
        live: list[tuple[int, Future, int]] = []  # (row, future, tenant)
        for i, (req, fut) in enumerate(batch):
            slot = self._slot_of.get(req.tenant)
            if slot is None:
                try:
                    slot = self._admit(req.tenant)
                except TableFullError as e:
                    fut.set_exception(e)
                    continue
            slots[i] = slot
            if req.observed_wait is not None:
                waits[i] = req.observed_wait
                has[i] = True
            live.append((i, fut, req.tenant))
        if not live:  # every request failed admission — nothing to serve
            return 0
        q = serve_asa.QueryBatch(
            slot=jax.numpy.asarray(slots),
            observed_wait=jax.numpy.asarray(waits),
            has_obs=jax.numpy.asarray(has))
        # pad to the one compiled (batch_size,) shape; the mask guards the
        # pad rows (copies of query 0) from ever touching the table
        qp, mask = pfleet.pad_batch(q, self.cfg.batch_size)
        self._table, dec = serve_asa.serve_step(self._table, qp, mask,
                                                mesh=self._mesh)
        lead = np.asarray(dec.lead_s)
        expected = np.asarray(dec.expected_s)
        entropy = np.asarray(dec.entropy)
        for i, fut, tenant in live:
            fut.set_result(Decision(tenant, float(lead[i]),
                                    float(expected[i]), float(entropy[i])))
        self._batches += 1
        self._decisions += len(live)
        if (self.cfg.checkpoint_every
                and self._batches % self.cfg.checkpoint_every == 0):
            self.save_async()
        return len(live)

    def _run(self) -> None:
        while not self._stop.is_set():
            if self.step_once() == 0:
                # queue stayed empty for batch_wait_s: yield briefly so a
                # stopped server exits promptly (sqswatcher's idle poll)
                self._stop.wait(self.cfg.batch_wait_s)

    def start(self) -> None:
        """Run the serve loop in a daemon thread."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="asa-serve-loop")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._stop.clear()
        if self._ckpt_handle is not None:
            self._ckpt_handle.result()
            self._ckpt_handle = None

    # --------------------------------------------------------- durability
    def _state_tree(self) -> dict:
        # the full durable state: posteriors AND the host bookkeeping
        # that shapes future admissions (the dirty mask and the
        # admissions counter that salts reset keys) — so a restored
        # server admits new tenants with the exact keys the
        # uninterrupted one would have used
        dirty = np.zeros(self.cfg.n_slots, bool)
        if self._dirty:
            dirty[list(self._dirty)] = True
        return {"table": self._table, "tenant_ids": self._tenant_ids,
                "admissions": np.int32(self._admissions), "dirty": dirty}

    def save(self, step: Optional[int] = None) -> Path:
        """Synchronous snapshot through the checkpoint codec."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        return checkpoint.save(self._state_tree(), self.cfg.checkpoint_dir,
                               self._batches if step is None else step)

    def save_async(self, step: Optional[int] = None) -> checkpoint.AsyncSave:
        """Background snapshot; a previously-failed save raises HERE (the
        handle's result() re-raises), so cadenced saves can't fail
        silently batch after batch."""
        assert self.cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        if self._ckpt_handle is not None:
            self._ckpt_handle.result()
        self._ckpt_handle = checkpoint.save_async(
            self._state_tree(), self.cfg.checkpoint_dir,
            self._batches if step is None else step)
        return self._ckpt_handle

    @classmethod
    def restore(cls, cfg: ServeConfig, step: Optional[int] = None,
                mesh=None) -> "ASAServer":
        """Resume a server from its checkpoint: posteriors (PRNG keys
        included) and the tenant map come back exactly, so the restarted
        server's decisions are bitwise those of the uninterrupted one."""
        assert cfg.checkpoint_dir, "ServeConfig.checkpoint_dir unset"
        if step is None:
            step = checkpoint.latest_step(cfg.checkpoint_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoint under {cfg.checkpoint_dir}")
        server = cls(cfg, mesh=mesh)
        tree = checkpoint.restore(server._state_tree(),
                                  cfg.checkpoint_dir, step)
        server._table = tree["table"]
        # np.array (copy): asarray on a jax array yields a read-only view
        server._tenant_ids = np.array(tree["tenant_ids"], np.int32)
        server._slot_of = {int(t): s
                           for s, t in enumerate(server._tenant_ids)
                           if t >= 0}
        occupied = set(server._slot_of.values())
        server._free = deque(s for s in range(cfg.n_slots)
                             if s not in occupied)
        # the dirty mask and admissions salt come back exactly, so a
        # post-restart admission resets (or not) with the very key the
        # uninterrupted server would have used
        dirty = np.asarray(tree["dirty"])
        server._dirty = {s for s in range(cfg.n_slots) if dirty[s]}
        server._admissions = int(tree["admissions"])
        server._batches = step
        return server

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        return {
            "batches": self._batches,
            "decisions": self._decisions,
            "tenants": self.n_tenants,
            "n_slots": self.cfg.n_slots,
            "deferred": len(self._deferred),
        }


def estimate_lead(state: core_asa.ASAState, bins) -> jax.Array:
    """Convenience: the submit-lead-time a single estimator answers
    (MAP wait — what ``DecisionBatch.lead_s`` reports per tenant)."""
    return core_asa.map_wait(state, bins)
