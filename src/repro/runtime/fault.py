"""Failure detection + straggler mitigation.

Heartbeat tracking per worker (pod slice); a missed-deadline policy drives
both failure handling (restart from the last checkpoint on a shrunken mesh
— runtime.elastic) and straggler re-execution (the paper's own
re-submission-on-miss logic from §4.8, applied to tasks instead of jobs):
a task is re-issued when its runtime exceeds the q-quantile of completed
durations by a configurable factor.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class WorkerState:
    id: int
    last_heartbeat: float
    healthy: bool = True


class HeartbeatTracker:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.workers: dict[int, WorkerState] = {}
        self.on_failure: list[Callable[[int], None]] = []

    def register(self, worker_id: int, now: float) -> None:
        self.workers[worker_id] = WorkerState(worker_id, now)

    def beat(self, worker_id: int, now: float) -> None:
        w = self.workers.get(worker_id)
        if w is not None:
            w.last_heartbeat = now
            w.healthy = True

    def sweep(self, now: float) -> list[int]:
        """Mark/report newly failed workers."""
        failed = []
        for w in self.workers.values():
            if w.healthy and now - w.last_heartbeat > self.timeout_s:
                w.healthy = False
                failed.append(w.id)
                for cb in self.on_failure:
                    cb(w.id)
        return failed

    def healthy_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.healthy)


@dataclass
class StragglerPolicy:
    """Deadline = quantile(completed) × factor (+ floor)."""
    quantile: float = 0.9
    factor: float = 2.0
    min_samples: int = 5
    floor_s: float = 1.0

    def deadline(self, completed_durations: list[float]) -> Optional[float]:
        if len(completed_durations) < self.min_samples:
            return None
        q = float(np.quantile(np.asarray(completed_durations),
                              self.quantile))
        return max(q * self.factor, self.floor_s)


@dataclass
class TaskAttempt:
    task_id: int
    started_at: float
    finished_at: Optional[float] = None


class StragglerMitigator:
    """Tracks per-task attempts; tells the runner which to re-issue."""

    def __init__(self, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.attempts: dict[int, list[TaskAttempt]] = {}
        self.durations: list[float] = []

    def start(self, task_id: int, now: float) -> None:
        self.attempts.setdefault(task_id, []).append(TaskAttempt(task_id, now))

    def finish(self, task_id: int, now: float) -> None:
        for a in self.attempts.get(task_id, []):
            if a.finished_at is None:
                a.finished_at = now
                self.durations.append(now - a.started_at)
                break

    def stragglers(self, now: float) -> list[int]:
        d = self.policy.deadline(self.durations)
        if d is None:
            return []
        out = []
        for tid, atts in self.attempts.items():
            running = [a for a in atts if a.finished_at is None]
            if running and all(now - a.started_at > d for a in running):
                out.append(tid)
        return out
