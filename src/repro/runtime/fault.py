"""Failure detection, straggler mitigation + capacity-fault schedules.

Heartbeat tracking per worker (pod slice); a missed-deadline policy drives
both failure handling (restart from the last checkpoint on a shrunken mesh
— runtime.elastic) and straggler re-execution (the paper's own
re-submission-on-miss logic from §4.8, applied to tasks instead of jobs):
a task is re-issued when its runtime exceeds the q-quantile of completed
durations by a configurable factor.

``FaultSchedule`` is the data form of the same failure model: a sorted
list of capacity events (node failures, graceful drains, recoveries /
grows) that ``repro.xsim`` folds into its jitted event scan as
per-scenario arrays — the robustness scenario families (faulty, elastic,
preempt) are built from these schedules (see ``xsim.families``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

# --- capacity-event kinds (xsim mirrors these in its fault arrays) ---------
FAULT_FAIL = 1   # nodes die NOW: running jobs are killed to cover the loss
FAULT_DRAIN = 2  # nodes drain: leave as their work completes (no kills)
FAULT_GROW = 3   # nodes join: recovery or elastic grow


@dataclass(frozen=True)
class CapacityEvent:
    """One capacity change: at time ``t`` (absolute simulation seconds),
    ``frac`` of the machine's *original* total cores fail/drain/join.

    ``frac`` is a fraction so one schedule applies across center
    geometries; it is converted to (rounded, integer-exact in f32) core
    counts against a concrete machine by ``FaultSchedule.as_arrays``.
    Shrinks larger than the machine present at the event are clamped by
    the engine — you can never lose more cores than exist.
    """

    t: float
    frac: float
    kind: int

    def __post_init__(self) -> None:
        if not (np.isfinite(self.t) and self.t >= 0.0):
            raise ValueError(f"event time must be finite >= 0, got {self.t}")
        if not (0.0 < self.frac):
            raise ValueError(f"capacity fraction must be > 0, got "
                             f"{self.frac}")
        if self.kind not in (FAULT_FAIL, FAULT_DRAIN, FAULT_GROW):
            raise ValueError(f"unknown fault kind {self.kind}")
        if self.kind != FAULT_GROW and self.frac > 1.0:
            raise ValueError(
                f"fail/drain fraction must be <= 1, got {self.frac}")


def fail(t: float, frac: float) -> CapacityEvent:
    """Nodes die at ``t``: their running jobs are killed and requeued."""
    return CapacityEvent(t, frac, FAULT_FAIL)


def drain(t: float, frac: float) -> CapacityEvent:
    """Nodes drain from ``t``: capacity leaves as running work completes."""
    return CapacityEvent(t, frac, FAULT_DRAIN)


def grow(t: float, frac: float) -> CapacityEvent:
    """Nodes join at ``t`` (recovery after a failure, or elastic grow)."""
    return CapacityEvent(t, frac, FAULT_GROW)


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, time-sorted list of capacity events.

    The empty schedule is the no-fault case: ``as_arrays`` pads with
    ``+inf`` times, which the xsim engine treats as "no event" — a
    dynamically empty schedule is bit-identical to the fault-free
    program (pinned by tests/test_xsim_faults.py).
    """

    events: tuple[CapacityEvent, ...] = ()

    def __post_init__(self) -> None:
        evs = tuple(sorted(self.events, key=lambda e: e.t))
        object.__setattr__(self, "events", evs)

    def __len__(self) -> int:
        return len(self.events)

    def as_arrays(self, max_events: int, total_cores: float
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(times, core deltas, kinds) padded to ``max_events`` slots.

        Times are f32 sorted ascending (+inf padding); deltas are
        ``round(frac · total_cores)`` f32 cores (integer-exact below
        2^24, like every core count in the engine); kinds are i32.
        """
        if len(self.events) > max_events:
            raise ValueError(
                f"{len(self.events)} fault events > {max_events} slots "
                "(raise XSimConfig.n_faults)")
        t = np.full(max_events, np.inf, np.float32)
        c = np.zeros(max_events, np.float32)
        k = np.zeros(max_events, np.int32)
        for i, e in enumerate(self.events):
            t[i] = e.t
            c[i] = np.round(e.frac * total_cores)
            k[i] = e.kind
        return t, c, k


@dataclass
class WorkerState:
    id: int
    last_heartbeat: float
    healthy: bool = True


class HeartbeatTracker:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.workers: dict[int, WorkerState] = {}
        self.on_failure: list[Callable[[int], None]] = []

    def register(self, worker_id: int, now: float) -> None:
        self.workers[worker_id] = WorkerState(worker_id, now)

    def beat(self, worker_id: int, now: float) -> None:
        w = self.workers.get(worker_id)
        if w is not None:
            w.last_heartbeat = now
            w.healthy = True

    def sweep(self, now: float) -> list[int]:
        """Mark/report newly failed workers."""
        failed = []
        for w in self.workers.values():
            if w.healthy and now - w.last_heartbeat > self.timeout_s:
                w.healthy = False
                failed.append(w.id)
                for cb in self.on_failure:
                    cb(w.id)
        return failed

    def healthy_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.healthy)


@dataclass
class StragglerPolicy:
    """Deadline = quantile(completed) × factor (+ floor)."""
    quantile: float = 0.9
    factor: float = 2.0
    min_samples: int = 5
    floor_s: float = 1.0

    def deadline(self, completed_durations: list[float]) -> Optional[float]:
        if len(completed_durations) < self.min_samples:
            return None
        q = float(np.quantile(np.asarray(completed_durations),
                              self.quantile))
        return max(q * self.factor, self.floor_s)


@dataclass
class TaskAttempt:
    task_id: int
    started_at: float
    finished_at: Optional[float] = None


class StragglerMitigator:
    """Tracks per-task attempts; tells the runner which to re-issue."""

    def __init__(self, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.attempts: dict[int, list[TaskAttempt]] = {}
        self.durations: list[float] = []

    def start(self, task_id: int, now: float) -> None:
        self.attempts.setdefault(task_id, []).append(TaskAttempt(task_id, now))

    def finish(self, task_id: int, now: float) -> None:
        for a in self.attempts.get(task_id, []):
            if a.finished_at is None:
                a.finished_at = now
                self.durations.append(now - a.started_at)
                break

    def stragglers(self, now: float) -> list[int]:
        d = self.policy.deadline(self.durations)
        if d is None:
            return []
        out = []
        for tid, atts in self.attempts.items():
            running = [a for a in atts if a.finished_at is None]
            if running and all(now - a.started_at > d for a in running):
                out.append(tid)
        return out
