"""Global resource pool over multiple batch allocations (paper §3.1).

The Mesos 'unified view' adapted to pod-sliced accelerator fleets: each
batch job that starts contributes an Allocation (a set of slices); the pool
presents them as one elastic inventory from which stages claim resources.
Offer/claim semantics mirror Mesos offers; revocation mirrors preemption /
node failure (the fault module drives it).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class Allocation:
    """One batch-system allocation (a job that started)."""
    id: int
    slices: int                  # pod slices (or nodes) granted
    expires_at: Optional[float] = None
    healthy: bool = True


@dataclass
class Claim:
    id: int
    slices: int
    alloc_ids: list[int]


class ResourcePool:
    def __init__(self):
        self._allocs: dict[int, Allocation] = {}
        self._claims: dict[int, Claim] = {}
        self._ids = itertools.count(1)
        self._claimed_per_alloc: dict[int, int] = {}
        self.on_revoke: list[Callable[[Claim], None]] = []

    # ------------------------------------------------------------- supply
    def add_allocation(self, slices: int,
                       expires_at: Optional[float] = None) -> Allocation:
        a = Allocation(next(self._ids), slices, expires_at)
        self._allocs[a.id] = a
        self._claimed_per_alloc[a.id] = 0
        return a

    def remove_allocation(self, alloc_id: int) -> list[Claim]:
        """Allocation ended/failed: revoke claims that used it."""
        self._allocs.pop(alloc_id, None)
        self._claimed_per_alloc.pop(alloc_id, None)
        hit = [c for c in self._claims.values() if alloc_id in c.alloc_ids]
        for c in hit:
            del self._claims[c.id]
            for cb in self.on_revoke:
                cb(c)
        return hit

    # ------------------------------------------------------------- demand
    def available(self) -> int:
        return sum(
            a.slices - self._claimed_per_alloc.get(a.id, 0)
            for a in self._allocs.values() if a.healthy)

    def claim(self, slices: int) -> Optional[Claim]:
        """First-fit claim across allocations (may span several)."""
        if slices > self.available():
            return None
        remaining = slices
        used: list[int] = []
        for a in self._allocs.values():
            if not a.healthy:
                continue
            free = a.slices - self._claimed_per_alloc[a.id]
            take = min(free, remaining)
            if take > 0:
                self._claimed_per_alloc[a.id] += take
                used.append(a.id)
                remaining -= take
            if remaining == 0:
                break
        c = Claim(next(self._ids), slices, used)
        self._claims[c.id] = c
        return c

    def release(self, claim: Claim) -> None:
        if claim.id not in self._claims:
            return
        del self._claims[claim.id]
        # proportional release (claims record only the alloc ids)
        remaining = claim.slices
        for aid in claim.alloc_ids:
            if aid not in self._claimed_per_alloc:
                continue
            give = min(self._claimed_per_alloc[aid], remaining)
            self._claimed_per_alloc[aid] -= give
            remaining -= give
