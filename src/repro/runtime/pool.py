"""Global resource pool over multiple batch allocations (paper §3.1).

The Mesos 'unified view' adapted to pod-sliced accelerator fleets: each
batch job that starts contributes an Allocation (a set of slices); the pool
presents them as one elastic inventory from which stages claim resources.
Offer/claim semantics mirror Mesos offers; revocation mirrors preemption /
node failure (the fault module drives it).

Accounting is exact: a ``Claim`` records the per-allocation breakdown
``{alloc_id: slices}`` of what it holds, so release and revocation give
back precisely the slices each allocation contributed.  The pool invariant

    sum(claim.slices) == sum(claimed_per_alloc)  and
    0 <= claimed_per_alloc[a] <= alloc[a].slices for every allocation

holds after every operation (``check_invariants`` verifies it; the
hypothesis property test in tests/test_pool_properties.py drives random
claim/release/revoke/expiry mixes against it).

Allocations may carry an ``expires_at`` walltime (batch jobs end):
``sweep_expired(now)`` lapses every allocation past its deadline,
revoking its claims through the normal ``on_revoke`` path.  ``claim`` and
``available`` accept an optional ``now`` that sweeps first, so expired
inventory is never claimable.

Claims may carry an ``expires_at`` of their own — a **lease**: the holder
must keep renewing (``renew``) or ``sweep_expired(now)`` lapses the claim
exactly as an allocation failure would (slices returned, ``on_revoke``
fired).  This is the substrate for idle-LRU policies: the serving loop
leases one table slot per tenant and refreshes the lease on every
request, so a sweep revokes precisely the tenants that went cold.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class Allocation:
    """One batch-system allocation (a job that started)."""
    id: int
    slices: int                  # pod slices (or nodes) granted
    expires_at: Optional[float] = None
    healthy: bool = True


@dataclass
class Claim:
    id: int
    slices: int
    # exact per-allocation breakdown of the claim — release/revoke give
    # back precisely what each allocation contributed
    alloc_slices: dict[int, int] = field(default_factory=dict)
    # lease deadline: sweep_expired(now >= expires_at) revokes the claim;
    # None = held until released/revoked (the pre-lease behavior)
    expires_at: Optional[float] = None

    @property
    def alloc_ids(self) -> list[int]:
        return list(self.alloc_slices)


class ResourcePool:
    def __init__(self):
        self._allocs: dict[int, Allocation] = {}
        self._claims: dict[int, Claim] = {}
        self._ids = itertools.count(1)
        self._claimed_per_alloc: dict[int, int] = {}
        self.on_revoke: list[Callable[[Claim], None]] = []

    # ------------------------------------------------------------- supply
    def add_allocation(self, slices: int,
                       expires_at: Optional[float] = None) -> Allocation:
        a = Allocation(next(self._ids), slices, expires_at)
        self._allocs[a.id] = a
        self._claimed_per_alloc[a.id] = 0
        return a

    def remove_allocation(self, alloc_id: int) -> list[Claim]:
        """Allocation ended/failed: revoke claims that used it.

        A revoked claim that spanned several allocations hands its slices
        back to every *surviving* allocation — the whole claim dies (its
        holder lost part of its resources), but the other allocations'
        capacity must not leak.
        """
        self._allocs.pop(alloc_id, None)
        self._claimed_per_alloc.pop(alloc_id, None)
        hit = [c for c in self._claims.values()
               if alloc_id in c.alloc_slices]
        for c in hit:
            del self._claims[c.id]
            for aid, amt in c.alloc_slices.items():
                if aid in self._claimed_per_alloc:
                    self._claimed_per_alloc[aid] -= amt
            for cb in self.on_revoke:
                cb(c)
        return hit

    def sweep_expired(self, now: float) -> list[Claim]:
        """Lapse every allocation AND every claim lease past its deadline.

        The batch system reclaimed those nodes whether we noticed or not;
        this makes the pool notice: each expired allocation leaves the
        inventory and its claims are revoked through ``on_revoke`` exactly
        as a failure would.  Expired claim leases (``Claim.expires_at``)
        are then revoked the same way — slices returned to their
        allocations, ``on_revoke`` fired once.  Returns the revoked
        claims (allocation-driven first, then lapsed leases, oldest
        deadline first — a deterministic idle-LRU order).
        """
        expired = [a.id for a in self._allocs.values()
                   if a.expires_at is not None and a.expires_at <= now]
        revoked: list[Claim] = []
        for aid in expired:
            revoked.extend(self.remove_allocation(aid))
        lapsed = sorted((c for c in self._claims.values()
                         if c.expires_at is not None
                         and c.expires_at <= now),
                        key=lambda c: (c.expires_at, c.id))
        for c in lapsed:
            self.release(c)
            for cb in self.on_revoke:
                cb(c)
            revoked.append(c)
        return revoked

    # ------------------------------------------------------------- demand
    def available(self, now: Optional[float] = None) -> int:
        if now is not None:
            self.sweep_expired(now)
        return sum(
            a.slices - self._claimed_per_alloc.get(a.id, 0)
            for a in self._allocs.values() if a.healthy)

    def claim(self, slices: int, now: Optional[float] = None,
              expires_at: Optional[float] = None) -> Optional[Claim]:
        """First-fit claim across allocations (may span several).
        ``expires_at`` makes it a lease: renew it or the next
        ``sweep_expired`` past the deadline revokes it."""
        if now is not None:
            self.sweep_expired(now)
        if slices > self.available():
            return None
        remaining = slices
        used: dict[int, int] = {}
        for a in self._allocs.values():
            if not a.healthy:
                continue
            free = a.slices - self._claimed_per_alloc[a.id]
            take = min(free, remaining)
            if take > 0:
                self._claimed_per_alloc[a.id] += take
                used[a.id] = take
                remaining -= take
            if remaining == 0:
                break
        c = Claim(next(self._ids), slices, used, expires_at=expires_at)
        self._claims[c.id] = c
        return c

    def renew(self, claim: Claim,
              expires_at: Optional[float]) -> bool:
        """Push a live lease's deadline (``None`` clears it); returns
        False when the claim is already dead — the holder learns its
        lease lapsed instead of writing to a ghost."""
        live = self._claims.get(claim.id)
        if live is None:
            return False
        live.expires_at = expires_at
        return True

    def release(self, claim: Claim) -> None:
        if claim.id not in self._claims:
            return
        del self._claims[claim.id]
        for aid, amt in claim.alloc_slices.items():
            if aid in self._claimed_per_alloc:
                self._claimed_per_alloc[aid] -= amt

    # ---------------------------------------------------------- invariant
    def check_invariants(self) -> list[str]:
        """Return violations of the pool invariant (empty ⇒ consistent)."""
        errs: list[str] = []
        claimed = sum(c.slices for c in self._claims.values())
        counted = sum(self._claimed_per_alloc.values())
        if claimed != counted:
            errs.append(f"sum(claims)={claimed} != "
                        f"sum(claimed_per_alloc)={counted}")
        for aid, amt in self._claimed_per_alloc.items():
            a = self._allocs.get(aid)
            if a is None:
                errs.append(f"claimed_per_alloc references dead alloc {aid}")
            elif not 0 <= amt <= a.slices:
                errs.append(f"alloc {aid}: claimed {amt} outside "
                            f"[0, {a.slices}]")
        for c in self._claims.values():
            if sum(c.alloc_slices.values()) != c.slices:
                errs.append(f"claim {c.id}: breakdown sums to "
                            f"{sum(c.alloc_slices.values())}, "
                            f"not {c.slices}")
            for aid in c.alloc_slices:
                if aid not in self._allocs:
                    errs.append(f"claim {c.id} references dead alloc {aid}")
        return errs
