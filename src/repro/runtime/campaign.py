"""ASA-driven campaign scheduler: the paper's technique applied to training
campaigns on a batch-managed accelerator fleet.

A *campaign* is a sequence of stages with different pod geometries
(data-prep → pretrain → anneal → SFT → eval, or an elastic-resize plan
inside one run). Exactly like the paper's workflow stages, each stage's
allocation must be requested from a queue whose wait ASA learns — the
pro-active request for stage y is submitted at ``E[end_{y-1}] − a_y``.

This module glues core.asa to sched.queue_sim (the calibrated cluster
substrate) and runtime.{pool,elastic,checkpoint}: when a stage's allocation
arrives, the pool grows; when a stage ends, the campaign snapshots and
resizes. It is the end-to-end integration exercised by
examples/campaign_schedule.py and tests/test_campaign.py.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.sched.queue_sim import QueueSim
from repro.sched.strategies import ASAEstimator
from repro.runtime.pool import ResourcePool


@dataclass(frozen=True)
class CampaignStage:
    name: str
    slices: int          # pod slices needed (the "job geometry")
    duration_s: float    # expected execution time
    arch: str = ""       # arch id this stage trains/serves (bookkeeping)


@dataclass
class StageOutcome:
    name: str
    slices: int
    submit_t: float
    alloc_start_t: float
    compute_start_t: float
    compute_end_t: float
    predicted_wait_s: float
    real_wait_s: float
    perceived_wait_s: float


@dataclass
class CampaignReport:
    outcomes: list[StageOutcome] = field(default_factory=list)

    @property
    def makespan_s(self) -> float:
        return (self.outcomes[-1].compute_end_t
                - self.outcomes[0].submit_t) if self.outcomes else 0.0

    @property
    def total_perceived_wait_s(self) -> float:
        return sum(o.perceived_wait_s for o in self.outcomes)

    @property
    def slice_hours(self) -> float:
        """Charged slice-hours: width × (hold time incl. perceived wait)."""
        return sum(
            o.slices * (o.compute_end_t - o.alloc_start_t)
            for o in self.outcomes) / 3600.0


class CampaignScheduler:
    """Pro-active (ASA) stage scheduling over a queue-managed fleet."""

    def __init__(self, sim: QueueSim, est: Optional[ASAEstimator] = None,
                 pool: Optional[ResourcePool] = None):
        self.sim = sim
        self.est = est or ASAEstimator()
        self.pool = pool or ResourcePool()

    def run(self, stages: list[CampaignStage]) -> CampaignReport:
        """Pro-active CASCADE (same scheme as sched.strategies.run_asa):
        stage i+1's request is submitted at E[end_i] − a_{i+1} where E[end_i]
        chains the *predicted* waits — several stage requests can be queued
        concurrently, so deep queue waits overlap earlier stages' waits."""
        rep = CampaignReport()
        sim, est = self.sim, self.est
        n = len(stages)
        jobs: list = [None] * n
        preds = [0.0] * n

        def schedule(i: int, expected_prev_end: float, dep_id) -> None:
            a = est.predict()
            preds[i] = a
            submit_at = max(sim.now, expected_prev_end - a)

            def do():
                j = sim.submit(stages[i].slices, stages[i].duration_s,
                               depend_on=dep_id, user="campaign")
                jobs[i] = j
                expected_end = (max(sim.now + a, expected_prev_end)
                                + stages[i].duration_s)
                if i + 1 < n:
                    schedule(i + 1, expected_end, j.id)

            sim.at(submit_at, do)

        j0 = sim.submit(stages[0].slices, stages[0].duration_s,
                        user="campaign")
        jobs[0] = j0
        a0 = est.predict()
        if n > 1:
            schedule(1, j0.submit_time + a0 + stages[0].duration_s, j0.id)

        prev_compute_end = None
        for i, st in enumerate(stages):
            while jobs[i] is None or jobs[i].start_time is None:
                sim._step()
            job = jobs[i]
            self.pool.add_allocation(st.slices)
            real_wait = job.start_time - job.submit_time
            est.learn(real_wait)
            compute_start = (job.start_time if i == 0
                             else max(job.start_time, prev_compute_end))
            compute_end = compute_start + st.duration_s
            pwt = (real_wait if i == 0
                   else max(0.0, job.start_time - prev_compute_end))
            rep.outcomes.append(StageOutcome(
                name=st.name, slices=st.slices, submit_t=job.submit_time,
                alloc_start_t=job.start_time,
                compute_start_t=compute_start, compute_end_t=compute_end,
                predicted_wait_s=preds[i], real_wait_s=real_wait,
                perceived_wait_s=pwt))
            prev_compute_end = compute_end
        sim.run_until(prev_compute_end)
        return rep
