"""Elastic remeshing: grow/shrink the data (FSDP) axis between stages.

The paper's per-stage resource changes map here to changing the mesh's
``data`` extent. Because checkpoints are shape-canonical (runtime.checkpoint)
and shardings are recomputed per mesh (parallel.sharding), a resize is:

  1. (optional) pro-active allocation request via the ASA campaign scheduler,
  2. drain + snapshot (async checkpoint),
  3. build the new mesh, recompute ShardingRules,
  4. restore the snapshot with the new shardings (device_put does the
     all-to-all placement),
  5. resume the step function jitted for the new mesh.

``reshard_plan`` additionally reports, per parameter, old/new specs and the
per-device bytes that must move — the number a scheduler needs to estimate
resize cost (and what ASA learns to hide in the queue-wait overlap).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.parallel.sharding import ShardingRules


@dataclass
class ReshardEntry:
    path: str
    old_spec: str
    new_spec: str
    bytes_total: int
    moves: bool


def reshard_plan(params, old_rules: ShardingRules,
                 new_rules: ShardingRules) -> list[ReshardEntry]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    plan = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        old = old_rules.spec_for(pstr, leaf.shape)
        new = new_rules.spec_for(pstr, leaf.shape)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        # a leaf moves if its spec changed OR it is sharded over an axis
        # whose extent changed (same spec string, different shard shape)
        axes_used = {a for part in new if part
                     for a in ((part,) if isinstance(part, str) else part)}
        size_changed = any(
            old_rules.mesh.shape.get(a) != new_rules.mesh.shape.get(a)
            for a in axes_used)
        plan.append(ReshardEntry(
            path=pstr, old_spec=str(old), new_spec=str(new),
            bytes_total=nbytes,
            moves=(str(old) != str(new)) or size_changed))
    return plan


def apply_resize(tree, new_mesh, new_rules: ShardingRules):
    """Re-place every leaf under the new mesh's shardings."""
    shardings = new_rules.tree_shardings(tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
