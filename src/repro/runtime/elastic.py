"""Elastic remeshing: grow/shrink the data (FSDP) axis between stages.

The paper's per-stage resource changes map here to changing the mesh's
``data`` extent. Because checkpoints are shape-canonical (runtime.checkpoint)
and shardings are recomputed per mesh (parallel.sharding), a resize is:

  1. (optional) pro-active allocation request via the ASA campaign scheduler,
  2. drain + snapshot (async checkpoint),
  3. build the new mesh, recompute ShardingRules,
  4. restore the snapshot with the new shardings (device_put does the
     all-to-all placement),
  5. resume the step function jitted for the new mesh.

``reshard_plan`` additionally reports, per parameter, old/new specs and the
per-device bytes that must move — the number a scheduler needs to estimate
resize cost (and what ASA learns to hide in the queue-wait overlap).

``resize_schedule`` is the center-side view of the same elasticity: a
sequence of live capacity changes (the malleable-job model of Dynamic
Fractional Resource Scheduling, arXiv 1106.4985) expressed as a
``runtime.fault.FaultSchedule`` that ``repro.xsim`` folds into its jitted
scan — graceful shrinks drain, preemptive shrinks kill-and-requeue.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import numpy as np

from repro.parallel.sharding import ShardingRules
from repro.runtime import fault as _fault


@dataclass
class ReshardEntry:
    path: str
    old_spec: str
    new_spec: str
    bytes_total: int
    moves: bool


def reshard_plan(params, old_rules: ShardingRules,
                 new_rules: ShardingRules) -> list[ReshardEntry]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    plan = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        old = old_rules.spec_for(pstr, leaf.shape)
        new = new_rules.spec_for(pstr, leaf.shape)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        # a leaf moves if its spec changed OR it is sharded over an axis
        # whose extent changed (same spec string, different shard shape)
        axes_used = {a for part in new if part
                     for a in ((part,) if isinstance(part, str) else part)}
        size_changed = any(
            old_rules.mesh.shape.get(a) != new_rules.mesh.shape.get(a)
            for a in axes_used)
        plan.append(ReshardEntry(
            path=pstr, old_spec=str(old), new_spec=str(new),
            bytes_total=nbytes,
            moves=(str(old) != str(new)) or size_changed))
    return plan


def apply_resize(tree, new_mesh, new_rules: ShardingRules):
    """Re-place every leaf under the new mesh's shardings."""
    shardings = new_rules.tree_shardings(tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings)


def resize_schedule(steps: Sequence[tuple[float, float]], *,
                    preempt: bool = False) -> _fault.FaultSchedule:
    """Live capacity plan → ``runtime.fault.FaultSchedule``.

    ``steps`` is ``[(t, delta_frac), ...]``: at absolute simulation time
    ``t`` the center's capacity changes by ``delta_frac`` of its original
    total cores. Positive deltas grow (nodes join); negative deltas
    shrink — gracefully by default (a DRAIN: nodes leave as their running
    work completes), or preemptively with ``preempt=True`` (a FAIL: the
    most recently started jobs on the lost nodes are killed and requeued,
    the xsim engine charges their lost core-seconds as restart overhead).
    """
    events = []
    for t, delta in steps:
        if delta == 0.0:
            raise ValueError(f"zero-delta resize step at t={t}")
        if delta > 0.0:
            events.append(_fault.grow(t, delta))
        elif preempt:
            events.append(_fault.fail(t, -delta))
        else:
            events.append(_fault.drain(t, -delta))
    return _fault.FaultSchedule(tuple(events))
