"""Sharded checkpoint/restore (msgpack + zstd), elastic across mesh shapes.

Layout: <dir>/step_<n>/
  manifest.json            — tree structure, shapes, dtypes, chunking, codec
  <leaf-id>.bin            — compressed little-endian ndarray bytes

Compression is zstd when the ``zstandard`` package is available and falls
back to stdlib ``zlib`` otherwise; the codec used at save time is recorded
in the manifest so checkpoints restore correctly across environments.

Design points for 1000+-node deployments (documented here, exercised at
container scale by the tests):
  * every leaf is written as an independent chunk → processes write disjoint
    files (no coordinator bottleneck); restore re-shards onto ANY mesh
    (elastic restart after node loss — the shapes, not the shardings, are
    canonical).
  * atomic publish: data files land first, `manifest.json` last, so a
    half-written checkpoint is never restorable; `latest_step` scans only
    manifest-complete directories.  A reused ``_tmp_step_*`` dir (a prior
    save of the same step crashed mid-write) is cleared before writing,
    so stale leaf files from the dead attempt can never be published
    under a fresh manifest.
  * async save: `save_async` snapshots to host memory synchronously (the
    jax.device_get) and hands serialization to a daemon thread — the train
    loop blocks only for the copy, not the compression/IO.  It returns an
    ``AsyncSave`` handle whose ``result()``/``join()`` RE-RAISE any
    background failure: a failed save must surface in the caller, not
    report success while the "latest" checkpoint silently stays stale.
  * integrity: the manifest records a CRC32 per leaf payload.  ``restore``
    verifies and raises :class:`CheckpointCorruptError` on mismatch (or a
    missing leaf file), and ``latest_step(..., verified=True)`` returns the
    newest step that passes ``verify_step`` — a torn or bit-rotted latest
    snapshot degrades to the previous good one instead of poisoning
    restore.  Pre-CRC manifests verify structurally only (files present).
"""

from __future__ import annotations

import json
import threading
import zlib
from pathlib import Path

import jax
import numpy as np

try:
    import zstandard
except ModuleNotFoundError:
    zstandard = None


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (CRC mismatch, missing
    leaf file, or an unreadable manifest).  ``latest_step(verified=True)``
    exists so callers can fall back to the previous good step instead of
    dying on this."""


def _compressor(level: int):
    if zstandard is not None:
        cctx = zstandard.ZstdCompressor(level=level)
        return "zstd", cctx.compress
    # zstd accepts levels up to 22; zlib tops out at 9
    return "zlib", lambda data: zlib.compress(data, min(level, 9))


_DCTX = zstandard.ZstdDecompressor() if zstandard is not None else None


def _decompress(codec: str, payload: bytes) -> bytes:
    if codec == "zstd":
        if _DCTX is None:
            raise RuntimeError(
                "checkpoint was written with zstd but the 'zstandard' "
                "package is not installed in this environment")
        return _DCTX.decompress(payload)
    if codec == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


def save(tree, directory: str | Path, step: int, *, level: int = 3) -> Path:
    directory = Path(directory)
    tmp = directory / f"_tmp_step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        # a previous save of this step died mid-write: clear its leftovers
        # so orphaned leaf files can't ride along under the new manifest
        # (restore reads strictly by manifest, but latest_step-driven
        # tooling lists the dir — and a renamed tree must be exactly what
        # this save wrote)
        for stale in tmp.iterdir():
            if stale.is_file():
                stale.unlink()
    tmp.mkdir(parents=True, exist_ok=True)
    codec, compress = _compressor(level)
    leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "codec": codec, "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        payload = compress(np.ascontiguousarray(arr).tobytes())
        (tmp / f"{name}.bin").write_bytes(payload)
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            # integrity: CRC of the compressed payload as written — what
            # verify_step/restore re-hash straight off disk, no decompress
            "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
            "nbytes": len(payload),
        })
    # atomic publish: manifest written into tmp, then dir renamed
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncSave:
    """Handle for a background ``save``; failures re-raise in the caller.

    ``join()``/``result()`` block for the writer thread and re-raise
    whatever it raised — a background save that failed must not look like
    a success (the pre-handle daemon thread swallowed every exception, so
    the "latest" checkpoint silently stayed stale).  ``result()`` returns
    the published checkpoint directory.
    """

    def __init__(self, thread: threading.Thread, step: int):
        self._thread = thread
        self.step = step
        self._exc: BaseException | None = None
        self._path: Path | None = None

    def done(self) -> bool:
        return not self._thread.is_alive()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"async save of step {self.step} still running")
        if self._exc is not None:
            raise self._exc

    def result(self, timeout: float | None = None) -> Path:
        self.join(timeout)
        assert self._path is not None
        return self._path


def save_async(tree, directory: str | Path, step: int, *,
               level: int = 3) -> AsyncSave:
    """Snapshot to host now; serialize+write in the background.

    Blocks only for the device→host copy.  Returns an :class:`AsyncSave`
    whose ``result()``/``join()`` re-raise any background failure.
    """
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    handle: AsyncSave

    def _work():
        try:
            handle._path = save(host_tree, directory, step, level=level)
        except BaseException as e:  # surfaced via join()/result()
            handle._exc = e

    t = threading.Thread(target=_work, daemon=True)
    handle = AsyncSave(t, step)
    t.start()
    return handle


def verify_step(directory: str | Path, step: int) -> list[str]:
    """Integrity-check one published checkpoint; returns the violations
    (empty ⇒ verified).  Checks: manifest readable, every leaf file
    present, and — for manifests that carry per-leaf CRCs — each payload
    hashes to its recorded ``crc32``.  Pre-CRC manifests verify
    structurally only (the files exist)."""
    d = Path(directory) / f"step_{step}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"step {step}: unreadable manifest: {e}"]
    errs: list[str] = []
    for meta in manifest.get("leaves", []):
        name = meta.get("name", "?")
        path = d / f"{name}.bin"
        try:
            payload = path.read_bytes()
        except OSError as e:
            errs.append(f"step {step}: leaf {name!r} unreadable: {e}")
            continue
        want = meta.get("crc32")
        if want is None:
            continue  # pre-CRC checkpoint: presence is all we can check
        got = zlib.crc32(payload) & 0xFFFFFFFF
        if got != int(want):
            errs.append(f"step {step}: leaf {name!r} CRC mismatch "
                        f"(manifest {int(want):#010x}, disk {got:#010x})")
    return errs


def latest_step(directory: str | Path,
                verified: bool = False) -> int | None:
    """Newest published step (manifest present).  With ``verified=True``
    steps are scanned newest-first and the first one passing
    :func:`verify_step` wins — a torn/corrupted latest snapshot degrades
    to the previous good one instead of being handed to ``restore``."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.glob("step_*"):
        if (d / "manifest.json").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    if not verified:
        return max(steps) if steps else None
    for step in sorted(steps, reverse=True):
        if not verify_step(directory, step):
            return step
    return None


def restore(example_tree, directory: str | Path, step: int,
            shardings=None):
    """Restore into the structure of ``example_tree``; if ``shardings``
    (a matching pytree of NamedShardings) is given, leaves are placed
    sharded — onto whatever mesh those shardings reference (elastic)."""
    directory = Path(directory) / f"step_{step}"
    try:
        manifest = json.loads((directory / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"step {step}: unreadable manifest: {e}") from e
    codec = manifest.get("codec", "zstd")  # pre-codec checkpoints were zstd
    by_name = {m["name"]: m for m in manifest["leaves"]}
    leaves, treedef = _leaf_paths(example_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (name, leaf), sh in zip(leaves, shard_leaves):
        meta = by_name[name]
        try:
            payload = (directory / f"{name}.bin").read_bytes()
        except OSError as e:
            raise CheckpointCorruptError(
                f"step {step}: leaf {name!r} unreadable: {e}") from e
        want = meta.get("crc32")
        if want is not None:
            got = zlib.crc32(payload) & 0xFFFFFFFF
            if got != int(want):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {name!r} CRC mismatch (manifest "
                    f"{int(want):#010x}, disk {got:#010x}); use "
                    f"latest_step(verified=True) to fall back to the "
                    f"newest verified step")
        raw = _decompress(codec, payload)
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(
            meta["shape"]).copy()
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
