"""repro.runtime — the paper's §3.1 'unified view' adapted to multi-pod
training: resource pool, elastic remeshing, checkpoint/restart, fault &
straggler handling, and the ASA-driven campaign scheduler."""
