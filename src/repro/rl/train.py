"""REINFORCE-with-baseline training for the submission-policy head.

Plain SGD, no optimizer library: per iteration a fresh ``ScenarioGrid``
resample (new background draws, same cell structure → the jitted sweep
recompiles nothing) is rolled out with stochastic actions, the batch-mean
reward is the baseline, advantages are normalized, and the policy
gradient

    ∇ E[R] ≈ mean_b [ Â_b · Σ_y ∇ log π(a_by | o_by) ]

is taken through a *replayed* log-prob pass over the recorded
``(obs, act)`` buffers — the environment scan itself is never
differentiated (actions are discrete; REINFORCE needs no env gradients),
so the update is a tiny dense computation regardless of simulator depth.

``evaluate`` reruns a held-out grid with all five strategies (BigJob /
Per-Stage / ASA / ASA-Naive / the learned head, greedy actions) on
identical per-seed machines, the Table-1 comparison setting.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.rl import policy as P
from repro.rl import rollout
from repro.xsim.families import FAMILIES, family_grid
from repro.xsim.grid import XSimConfig, warm_fleet
from repro.xsim.state import ASA, ASA_NAIVE, BIGJOB, PER_STAGE, RL
from repro.xsim import policies as xpolicies


@dataclass(frozen=True)
class TrainConfig:
    """Knobs for one training run (defaults: the full 30-iteration
    recipe; the CI smoke recipe is ``benchmarks.rl_train.SMOKE``)."""

    iters: int = 30
    lr: float = 0.3
    n_seeds: int = 8            # episodes per cell per iteration
    hidden: int = P.HIDDEN_DEFAULT
    seed: int = 0
    oh_weight: float = rollout.OH_WEIGHT_DEFAULT
    warm_rounds: int = 3        # §4.3 estimator warm-up before training
    center_names: Sequence[str] = ("hpc2n", "uppmax")
    workflows: Sequence[str] = ("montage", "blast", "statistics")
    shrink: float = 1.0 / 64.0
    n_shards: int | None = None  # device-parallel rollouts (None = vmap)
    family: str = "clean"       # robustness scenario family for every
    #   grid this run touches (repro.xsim.families): train rollouts,
    #   estimator warm-up and held-out evaluation all see the same
    #   capacity-fault regime, so the head learns — and is judged —
    #   under the non-stationary waits the family induces
    sim: XSimConfig = field(default_factory=lambda: XSimConfig(
        n_warm=24, n_backlog=16, n_arrivals=24, max_stages=9, t0=3600.0))

    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}; expected "
                             f"one of {FAMILIES}")


@dataclass
class TrainResult:
    params: P.PolicyParams
    init_params: P.PolicyParams
    rewards: list[float]        # batch-mean reward per iteration
    entropies: list[float]      # mean action entropy per iteration (nats)
    # per-iteration fleet observability summaries (repro.obs.metrics
    # counters over each rollout's final states, JSON-safe dicts) — the
    # rl_train telemetry record ships these as metrics.iterations
    telemetry: list[dict] = field(default_factory=list)


def _surrogate(params: P.PolicyParams, obs, act, adv) -> jax.Array:
    """-mean_b( Â_b · Σ_y log π(a_by|o_by) ); act == -1 slots masked."""
    mask = act >= 0
    lp = P.log_prob(params, obs, jnp.maximum(act, 0))
    per_ep = jnp.sum(jnp.where(mask, lp, 0.0), axis=-1)
    return -jnp.mean(adv * per_ep)


@functools.partial(jax.jit, static_argnames=("lr",))
def reinforce_step(params: P.PolicyParams, obs, act, reward,
                   lr: float) -> tuple[P.PolicyParams, jax.Array]:
    """One SGD step on the REINFORCE surrogate; returns (params, entropy).

    The baseline is the batch-mean reward; advantages are normalized to
    unit variance so ``lr`` is scale-free across reward regimes.
    """
    adv = reward - jnp.mean(reward)
    adv = adv / (jnp.std(adv) + 1e-6)
    grads = jax.grad(_surrogate)(params, obs, act, adv)
    new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    # mean policy entropy over the visited observations (diagnostics)
    lp = jax.nn.log_softmax(P.logits(params, obs), axis=-1)
    ent = -jnp.sum(jnp.exp(lp) * lp, axis=-1)
    mask = act >= 0
    ent = jnp.sum(jnp.where(mask, ent, 0.0)) / jnp.maximum(
        jnp.sum(mask), 1)
    return new, ent


def warmed_fleet(cfg: TrainConfig, grid_seed: int):
    """A §4.3-warmed per-geometry estimator fleet (the policy head reads
    the live posterior as features, so training starts from the same
    informed state the hand-designed ASA enjoys)."""
    warm_grid = family_grid(cfg.sim, cfg.family,
                            center_names=cfg.center_names,
                            workflows=cfg.workflows,
                            policy_ids=(PER_STAGE, ASA), n_seeds=2,
                            shrink=cfg.shrink, seed=grid_seed)
    fleet = xpolicies.init_fleet(int(warm_grid.geo_idx.max()) + 1)
    return warm_fleet(fleet, warm_grid, rounds=cfg.warm_rounds,
                      n_shards=cfg.n_shards)


def train(cfg: TrainConfig = TrainConfig()) -> TrainResult:
    """REINFORCE over ``cfg.iters`` grid resamples; returns the curve."""
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params = P.init_params(key, hidden=cfg.hidden)
    fleet = warmed_fleet(cfg, grid_seed=cfg.seed)

    rewards: list[float] = []
    entropies: list[float] = []
    telemetry: list[dict] = []
    for i in range(cfg.iters):
        grid = family_grid(cfg.sim, cfg.family,
                           center_names=cfg.center_names,
                           workflows=cfg.workflows,
                           policy_ids=(RL,), n_seeds=cfg.n_seeds,
                           shrink=cfg.shrink,
                           seed=cfg.seed * 10_000 + i + 1)
        final, _, traj = rollout.collect(grid, params, fleet,
                                         pred_seed=i + 1, rl_mode="sample",
                                         oh_weight=cfg.oh_weight,
                                         n_shards=cfg.n_shards)
        rewards.append(float(jnp.mean(traj.reward)))
        # fleet observability counters for this iteration's rollouts
        # (same jitted reduction every iteration — no recompiles)
        telemetry.append(obs_metrics.to_host(obs_metrics.sweep_summary(
            final, n_steps=grid.cfg.n_steps)))
        params, ent = reinforce_step(params, traj.obs, traj.act,
                                     traj.reward, cfg.lr)
        entropies.append(float(ent))
    return TrainResult(params=params, init_params=init_params,
                       rewards=rewards, entropies=entropies,
                       telemetry=telemetry)


def evaluate(params: P.PolicyParams, cfg: TrainConfig = TrainConfig(), *,
             eval_seed: int = 777, n_seeds: int = 8,
             oh_weight: float | None = None,
             fleet=None) -> dict[str, dict[str, float]]:
    """Held-out strategy comparison: all five policies, greedy actions.

    ``eval_seed`` keys background draws never seen in training (train
    grids use ``cfg.seed·10000 + i + 1``). ``fleet`` lets callers reuse
    one ``warmed_fleet(cfg, grid_seed=eval_seed)`` across evaluations of
    several heads on the same held-out grid (warming costs
    ``cfg.warm_rounds`` full sweeps). Returns
    ``{strategy: {twt_s, makespan_s, core_hours, oh_hours, reward,
    n}}`` means over the grid.
    """
    w = cfg.oh_weight if oh_weight is None else oh_weight
    if fleet is None:
        fleet = warmed_fleet(cfg, grid_seed=eval_seed)
    grid = family_grid(cfg.sim, cfg.family,
                       center_names=cfg.center_names,
                       workflows=cfg.workflows,
                       policy_ids=(BIGJOB, PER_STAGE, ASA, ASA_NAIVE, RL),
                       n_seeds=n_seeds, shrink=cfg.shrink, seed=eval_seed)
    _, m, traj = rollout.collect(grid, params, fleet, pred_seed=eval_seed,
                                 rl_mode="greedy", oh_weight=w,
                                 n_shards=cfg.n_shards)
    reward = np.asarray(traj.reward)
    m = {k: np.asarray(v) for k, v in m.items()}

    by: dict[str, list[int]] = {}
    for i, lab in enumerate(grid.labels):
        by.setdefault(lab["strategy"], []).append(i)
    out: dict[str, dict[str, float]] = {}
    for strat, idx in sorted(by.items()):
        out[strat] = {k: float(np.mean(m[k][idx]))
                      for k in ("twt_s", "makespan_s", "core_hours",
                                "oh_hours")}
        out[strat]["reward"] = float(np.mean(reward[idx]))
        out[strat]["n"] = len(idx)
    return out
