"""repro.rl — learned submission-policy head trained on vmapped xsim
rollouts.

ASA's §4 estimator learns *queue waits*; the submission policy that
consumes them stays hand-designed (BigJob / Per-Stage / ASA / ASA-Naive).
This package adds the next rung: a small MLP head that maps a jit-safe
observation of the scenario (queue state + the live Algorithm-1
posterior) to a distribution over the §4.3 wait bins, acting as the
submit-lead-time inside the batched ``repro.xsim`` engine (policy id 4).
The vmapped sweep is the experience generator — thousands of independent
scheduling episodes per jitted call — and training is REINFORCE with a
batch baseline over resampled scenario grids. See README.md.
"""

from repro.rl.features import FEATURE_NAMES, N_FEATURES, observe
from repro.rl.policy import (PolicyParams, act_greedy, act_sample,
                             init_params, log_prob, logits)
from repro.rl.rollout import Trajectory, collect, episode_rewards
from repro.rl.train import TrainConfig, TrainResult

# NOTE: the train()/evaluate() entry points live in repro.rl.train and are
# deliberately NOT re-exported here — a package attribute named `train`
# would shadow the submodule of the same name.

__all__ = [
    "FEATURE_NAMES", "N_FEATURES", "observe",
    "PolicyParams", "act_greedy", "act_sample", "init_params", "log_prob",
    "logits",
    "Trajectory", "collect", "episode_rewards",
    "TrainConfig", "TrainResult",
]
