"""Batched episode collection on the vmapped xsim engine.

One ``collect`` call runs a whole ``ScenarioGrid`` of learned-policy
scenarios as a single jitted ``vmap(lax.scan)`` sweep (policy id 4 in
``xsim.events``) and reads the trajectory back out of the final states:
the chain hook recorded every observation/action pair into the
``rl_obs``/``rl_act`` buffers, so the rollout needs no python-side
stepping — thousands of scheduling episodes per call, exactly the
experience generator the vmapped sweep was built to be.

The per-scenario reward mirrors ``compare.metrics``: the negative
perceived inter-stage waiting time (hours) minus an over-allocation
penalty on the OH core-hours the no-dependency world charges for early
starts (idle holds and cancel latencies). Maximizing it is the §4.5
trade-off ASA navigates with its estimator — here the policy head must
learn it from returns alone.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.xsim.grid import ScenarioGrid, run_grid
from repro.xsim.state import ScenarioState

# One wasted core-hour costs as much reward as one hour of perceived
# wait — the same exchange rate compare.metrics' core_hours column uses
# when it folds oh_hours into the total.
OH_WEIGHT_DEFAULT = 1.0


class Trajectory(NamedTuple):
    """REINFORCE batch: (B, S, F) obs, (B, S) actions, (B,) rewards.

    ``act == -1`` marks unused stage slots (shorter workflows, or stages
    the step budget never admitted); mask with ``act >= 0``.
    """

    obs: jax.Array
    act: jax.Array
    reward: jax.Array


def episode_rewards(metrics: dict[str, jax.Array],
                    oh_weight: float = OH_WEIGHT_DEFAULT) -> jax.Array:
    """(B,) rewards from a batched metrics dict (higher is better)."""
    return -(metrics["twt_s"] / 3600.0 + oh_weight * metrics["oh_hours"])


def trajectory(final: ScenarioState, metrics: dict[str, jax.Array],
               oh_weight: float = OH_WEIGHT_DEFAULT) -> Trajectory:
    """Read the recorded (obs, act, reward) batch out of a finished sweep."""
    return Trajectory(obs=final.rl_obs, act=final.rl_act,
                      reward=episode_rewards(metrics, oh_weight))


def collect(grid: ScenarioGrid, params, fleet=None, *, pred_seed: int = 1,
            rl_mode: str = "sample", oh_weight: float = OH_WEIGHT_DEFAULT,
            freed_mode: str = "ref", n_shards: int | None = None,
            mesh=None):
    """Run the grid under ``params`` and return (final, metrics, traj).

    ``rl_mode="sample"`` draws stochastic actions (training);
    ``"greedy"`` takes the argmax bin (evaluation). ``pred_seed``
    decorrelates the per-scenario action streams between iterations.
    ``n_shards``/``mesh`` shard the episode batch across devices (params
    replicated, trajectories gathered) — bit-identical to the default
    single-device vmap, so training curves don't depend on the device
    count.
    """
    final, m = run_grid(grid, fleet, pred_seed=pred_seed,
                        freed_mode=freed_mode, params=params,
                        rl_mode=rl_mode, n_shards=n_shards, mesh=mesh)
    return final, m, trajectory(final, m, oh_weight)
