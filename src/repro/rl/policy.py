"""The learned submission-policy head: a small pure-jax MLP.

Maps an ``(N_FEATURES,)`` observation (features.py) to logits over the m
§4.3 wait bins; the sampled/greedy bin value is the stage's
submit-lead-time a_y, consumed by the xsim §3.2 cascade exactly where
ASA's estimator draw would be (``events._chain_hook``, policy id 4).

Parameters are a NamedTuple pytree — they thread through ``jax.jit`` /
``jax.vmap`` / ``jax.grad`` untouched and broadcast across the fleet as a
closed-over constant of the batched sweep.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.features import N_FEATURES
from repro.xsim.state import M_BINS

HIDDEN_DEFAULT = 32


class PolicyParams(NamedTuple):
    """MLP weights: obs -> tanh hidden -> wait-bin logits."""

    w1: jax.Array  # (n_features, hidden)
    b1: jax.Array  # (hidden,)
    w2: jax.Array  # (hidden, m)
    b2: jax.Array  # (m,)


def init_params(key: jax.Array, n_features: int = N_FEATURES,
                hidden: int = HIDDEN_DEFAULT, m: int = M_BINS,
                scale: float = 0.1) -> PolicyParams:
    """Small-random init; the zero output bias starts the head near the
    uniform distribution over bins (maximum-entropy exploration)."""
    k1, k2 = jax.random.split(key)
    return PolicyParams(
        w1=scale * jax.random.normal(k1, (n_features, hidden), jnp.float32),
        b1=jnp.zeros(hidden, jnp.float32),
        w2=scale * jax.random.normal(k2, (hidden, m), jnp.float32),
        b2=jnp.zeros(m, jnp.float32),
    )


def n_params(params: PolicyParams) -> int:
    return sum(int(p.size) for p in params)


def logits(params: PolicyParams, obs: jax.Array) -> jax.Array:
    """(.., n_features) observations -> (.., m) wait-bin logits."""
    h = jnp.tanh(obs @ params.w1 + params.b1)
    return h @ params.w2 + params.b2


def act_sample(params: PolicyParams, obs: jax.Array,
               key: jax.Array) -> jax.Array:
    """Stochastic action (training rollouts): a ~ softmax(logits)."""
    return jax.random.categorical(key, logits(params, obs))


def act_greedy(params: PolicyParams, obs: jax.Array) -> jax.Array:
    """Deterministic action (evaluation): argmax of the logits."""
    return jnp.argmax(logits(params, obs), axis=-1)


def log_prob(params: PolicyParams, obs: jax.Array,
             action: jax.Array) -> jax.Array:
    """log pi(action | obs) for (.., n_features) obs and (..,) actions."""
    lp = jax.nn.log_softmax(logits(params, obs), axis=-1)
    return jnp.take_along_axis(lp, action[..., None], axis=-1)[..., 0]
