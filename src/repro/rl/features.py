"""Jit-safe observation featurizer for the learned submission policy.

``observe`` reads one stage's slice of a ``ScenarioState`` — plus the
scenario's live ``core.asa.ASAState`` posterior — into a fixed
``(N_FEATURES,)`` vector, inside the event scan (it is called from the
``events._chain_hook`` RL branch at the same instants ASA would sample a
wait estimate). Everything is pure indexing/reduction, so the whole
feature pipeline vmaps across the fleet.

Times and durations are log-compressed to the §4.3 wait-bin range
(``log1p(x)/log1p(1e5)``), fractions are already in [0, 1], and the
posterior entropy is normalized by ``log m`` — every feature lands in
O(1) so the MLP head needs no input whitening.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import asa
from repro.core.bins import MAX_WAIT_SECONDS
from repro.xsim.state import QUEUED, RL_FEATURES, RUNNING, ScenarioState

N_FEATURES = RL_FEATURES  # the constant lives in xsim.state (import cycle)

FEATURE_NAMES = (
    "bias",              # constant 1
    "free_frac",         # free cores / machine size
    "queue_depth",       # queued jobs / table size
    "queued_work",       # queued core demand / machine size (capped at 4x)
    "running_frac",      # running jobs / table size
    "stage_cores",       # this stage's width / machine size
    "stage_duration",    # log1p(t_y) / log1p(1e5)
    "stage_index",       # y / max_stages
    "pred_eta",          # log1p(max(E_prev - now, 0)) / log1p(1e5)
    "map_wait",          # log1p(posterior MAP wait) / log1p(1e5)
    "expected_wait",     # log1p(posterior mean wait) / log1p(1e5)
    "entropy",           # posterior entropy / log m
)
assert len(FEATURE_NAMES) == N_FEATURES

_LOG_SCALE = float(jnp.log1p(MAX_WAIT_SECONDS))


def _logt(x: jax.Array) -> jax.Array:
    """Compress a nonnegative time/duration to ~[0, 1]."""
    return jnp.log1p(jnp.maximum(x, 0.0)) / _LOG_SCALE


def observe(s: ScenarioState, stage: jax.Array, row: jax.Array,
            pred_ee: jax.Array, now: jax.Array,
            bins: jax.Array) -> jax.Array:
    """Featurize stage ``stage`` (job-table row ``row``) at time ``now``.

    ``pred_ee`` is the predecessor chain's expected end E_{y-1} (-inf for
    stage 0 — the time-to-predecessor feature then reads 0). ``row`` must
    be pre-clipped to the table.
    """
    queued = s.status == QUEUED
    running = s.status == RUNNING
    n = jnp.float32(s.status.shape[0])
    m = s.est.log_p.shape[-1]
    post = asa.posterior_features(s.est, bins)
    return jnp.stack([
        jnp.float32(1.0),
        s.free / s.total,
        jnp.sum(queued) / n,
        jnp.minimum(jnp.sum(jnp.where(queued, s.cores, 0.0)) / s.total, 4.0),
        jnp.sum(running) / n,
        s.cores[row] / s.total,
        _logt(s.duration[row]),
        stage.astype(jnp.float32) / s.wf_rows.shape[0],
        _logt(pred_ee - now),
        _logt(post[0]),
        _logt(post[1]),
        post[2] / jnp.log(jnp.float32(m)),
    ])
