"""Request-lifecycle tracing + live metrics for the ASA serving loop.

``obs.trace`` watches the *device* (per-scenario event rings appended
inside the jitted scan); this module watches the *server*: every request
through ``serve.loop.ASAServer`` leaves a lifecycle trail

    enqueue → (dedup/defer)* → batch-form → pad → device step →
    scatter-read → future-resolve

recorded as host-side span events, plus batch-level annotations (batch
size, pad fraction, deferred-duplicate count, admissions/evictions,
checkpoint-cadence stalls).  Everything funnels through one
:class:`ServeObs` object:

* a :class:`repro.obs.registry.Registry` of always-on counters/gauges/
  histograms (the data behind ``ASAServer.stats`` and the ``/metrics``
  scrape endpoint) — cheap enough to never turn off;
* an optional **span recorder** (``spans=True``): wall-clock span events
  in a bounded deque, exported through ``chrome_events()`` onto
  dedicated ``serve`` pid rows so ``obs.export.merged_chrome_trace`` can
  interleave the server timeline with the device event rings of the
  same run in one Perfetto file.  ``spans=False`` (the server default)
  records nothing and takes no timestamps — the serve hot path is then
  byte-for-byte the uninstrumented one apart from integer counter
  bumps, and decisions are bit-identical either way (pinned by
  tests/test_serve_obs.py).

Conservation contract (pinned by tests): every request that enters
``submit()`` produces **exactly one** ``enqueue`` event and **exactly
one** ``request`` resolve span — TableFullError resolutions and
eviction races included — and ``requests_total`` always equals
``resolved_total + failed_total + in-flight``.

Time base: spans are wall-clock (``time.perf_counter`` relative to the
``ServeObs`` epoch), while device rings are *simulated* seconds — the
merged trace interleaves the two clocks as separate pid rows, it does
not align them.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Optional

from repro.obs.registry import (FRACTION_BUCKETS, LATENCY_BUCKETS_S,
                                Registry)

# chrome pid rows for the serve-side timelines: far above any scenario
# pid (device rings use pid = scenario index; fleets are ≤ table slots,
# a few thousand), asserted against collisions at merge time
SERVE_PID = 1_000_000          # loop phases + admission/eviction lane
SERVE_REQUEST_PID = 1_000_001  # per-request lifecycle lane (tid = tenant)

TID_LOOP = 0        # sequential batch-phase spans
TID_ADMISSION = 1   # admit/evict/table_full instants

_US = 1_000_000.0

# the batch-phase span names, in hot-path order (docs + tests key on it)
PHASES = ("batch_form", "pad", "device_step", "scatter_read",
          "future_resolve", "checkpoint_stall")


def serve_registry() -> Registry:
    """The serving loop's metric set, pre-registered so scrapes expose
    every series from the first request (Prometheus dislikes series that
    appear mid-flight)."""
    r = Registry()
    c, g, h = r.counter, r.gauge, r.histogram
    c("asa_serve_requests_total", "requests entering submit()")
    c("asa_serve_resolved_total", "futures resolved with a Decision")
    c("asa_serve_failed_total", "futures resolved with an error")
    c("asa_serve_observations_total", "requests carrying an observed wait")
    c("asa_serve_deferrals_total",
      "requests held to a later batch by the dedup batcher")
    c("asa_serve_batches_total", "jitted decision steps dispatched")
    c("asa_serve_decisions_total", "decisions answered (live batch rows)")
    c("asa_serve_padded_rows_total",
      "pad rows dispatched (batch_size - live rows, summed)")
    c("asa_serve_admissions_total", "tenant slot admissions")
    c("asa_serve_evictions_total", "tenant evictions")
    c("asa_serve_evicted_requests_total",
      "lifetime request totals of evicted tenants, snapshotted at evict")
    c("asa_serve_table_full_total", "admissions refused: table full")
    c("asa_serve_checkpoints_total", "cadenced async snapshots started")
    c("asa_serve_checkpoint_stall_seconds_total",
      "serve-loop seconds spent collecting previous checkpoint handles")
    c("asa_serve_checkpoint_failures_total",
      "cadenced checkpoint saves that failed (contained; serving continues)")
    c("asa_serve_step_errors_total",
      "jitted decision steps that failed (batch futures got ServeStepError)")
    c("asa_serve_shed_total", "requests shed before dispatch (any reason)")
    c("asa_serve_shed_expired_total",
      "requests shed at batch-form: deadline already passed")
    c("asa_serve_shed_queue_full_total",
      "requests shed at submit: bounded ingress queue full")
    c("asa_serve_lease_evictions_total",
      "idle tenants evicted by pool-lease LRU under table pressure")
    c("asa_serve_crashes_total", "serve-loop crashes (loop thread died)")
    c("asa_serve_restarts_total",
      "supervised restarts from the latest verified checkpoint")
    c("asa_serve_stop_drained_total",
      "queued/deferred requests failed with ServerStopped at stop()")
    g("asa_serve_loop_healthy",
      "1 while the serve loop thread is running, 0 after crash/stop")
    g("asa_serve_last_batch_age_seconds",
      "seconds since the loop last dispatched a batch (watchdog)")
    g("asa_serve_tenants", "admitted tenants (occupied slots)")
    g("asa_serve_free_slots", "unoccupied tenant slots")
    g("asa_serve_deferred", "requests parked in the deferred deque")
    g("asa_serve_inflight", "submitted but not yet resolved requests")
    h("asa_serve_request_latency_seconds", LATENCY_BUCKETS_S,
      "submit() to future resolution")
    h("asa_serve_device_step_seconds", LATENCY_BUCKETS_S,
      "jitted serve_step dispatch (async — excludes host-blocked wait)")
    h("asa_serve_scatter_read_seconds", LATENCY_BUCKETS_S,
      "host-blocked device->host decision read")
    h("asa_serve_batch_fill", FRACTION_BUCKETS,
      "live rows / batch_size per dispatched batch")
    return r


class ServeObs:
    """Registry + (optional) span recorder for one :class:`ASAServer`."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 spans: bool = True, span_capacity: int = 1 << 18):
        self.registry = registry if registry is not None else \
            serve_registry()
        self.spans = bool(spans)
        self.epoch = time.perf_counter()
        self.events: deque[dict] = deque(maxlen=span_capacity)
        self._appended = 0
        self._rid = itertools.count()
        # hot-path handles (attribute loads beat dict lookups per call)
        g = self.registry
        self.c_requests = g.counter("asa_serve_requests_total")
        self.c_resolved = g.counter("asa_serve_resolved_total")
        self.c_failed = g.counter("asa_serve_failed_total")
        self.c_observations = g.counter("asa_serve_observations_total")
        self.c_deferrals = g.counter("asa_serve_deferrals_total")
        self.c_batches = g.counter("asa_serve_batches_total")
        self.c_decisions = g.counter("asa_serve_decisions_total")
        self.c_padded = g.counter("asa_serve_padded_rows_total")
        self.c_admissions = g.counter("asa_serve_admissions_total")
        self.c_evictions = g.counter("asa_serve_evictions_total")
        self.c_evicted_requests = g.counter(
            "asa_serve_evicted_requests_total")
        self.c_table_full = g.counter("asa_serve_table_full_total")
        self.c_checkpoints = g.counter("asa_serve_checkpoints_total")
        self.c_ckpt_stall_s = g.counter(
            "asa_serve_checkpoint_stall_seconds_total")
        self.c_ckpt_failures = g.counter(
            "asa_serve_checkpoint_failures_total")
        self.c_step_errors = g.counter("asa_serve_step_errors_total")
        self.c_shed = g.counter("asa_serve_shed_total")
        self.c_shed_expired = g.counter("asa_serve_shed_expired_total")
        self.c_shed_queue_full = g.counter(
            "asa_serve_shed_queue_full_total")
        self.c_lease_evictions = g.counter(
            "asa_serve_lease_evictions_total")
        self.c_crashes = g.counter("asa_serve_crashes_total")
        self.c_restarts = g.counter("asa_serve_restarts_total")
        self.c_stop_drained = g.counter("asa_serve_stop_drained_total")
        self.g_loop_healthy = g.gauge("asa_serve_loop_healthy")
        self.g_last_batch_age = g.gauge(
            "asa_serve_last_batch_age_seconds")
        self.g_tenants = g.gauge("asa_serve_tenants")
        self.g_free_slots = g.gauge("asa_serve_free_slots")
        self.g_deferred = g.gauge("asa_serve_deferred")
        self.g_inflight = g.gauge("asa_serve_inflight")
        self.h_latency = g.histogram("asa_serve_request_latency_seconds")
        self.h_device_step = g.histogram("asa_serve_device_step_seconds")
        self.h_scatter_read = g.histogram(
            "asa_serve_scatter_read_seconds")
        self.h_batch_fill = g.histogram("asa_serve_batch_fill")

    # ------------------------------------------------------------ recording
    # Buffered events are plain tuples, NOT dicts — the recorder sits on
    # the per-request hot path, where a dict (and its args sub-dict)
    # per event measurably moves the bench's serve_obs_overhead_frac;
    # the dict form is built once, at export time.  Tuple layout:
    #   (ph, name, pid, tid, t, dur, rid, aux)
    # with rid None for loop-lane events and aux either an error string
    # (request lane) or an args dict (loop lane, a few per batch).

    def now(self) -> float:
        """Wall-clock mark; 0.0 when spans are off (no syscall paid)."""
        return time.perf_counter() if self.spans else 0.0

    def next_rid(self) -> int:
        """Monotone request id (itertools.count: GIL-atomic)."""
        return next(self._rid)

    def _push(self, ev: tuple) -> None:
        self._appended += 1
        self.events.append(ev)

    @property
    def events_dropped(self) -> int:
        return self._appended - len(self.events)

    def enqueue(self, rid: int, tenant: int, t: float) -> None:
        # hottest record site (once per request, producer thread):
        # _push is inlined on purpose
        if self.spans:
            self._appended += 1
            self.events.append(("i", "enqueue", SERVE_REQUEST_PID,
                                tenant, t, 0.0, rid, None))

    def defer(self, rid: int, tenant: int, t: float) -> None:
        self.c_deferrals.inc()
        if self.spans:
            self._appended += 1
            self.events.append(("i", "defer", SERVE_REQUEST_PID,
                                tenant, t, 0.0, rid, None))

    def resolve(self, rid: int, tenant: int, t_enqueue: float, t: float,
                error: Optional[str] = None) -> None:
        """One request left the system (Decision or error) — the span
        closes here whatever path it took."""
        if error is None:
            self.c_resolved.inc()
        else:
            self.c_failed.inc()
        self.g_inflight.dec()
        if self.spans:
            dur = max(t - t_enqueue, 0.0)
            self.h_latency.observe(dur)
            self._push(("X", "request", SERVE_REQUEST_PID, tenant,
                        t_enqueue, dur, rid, error))

    def resolve_many(self, reqs, t: float) -> None:
        """Bulk success-resolve for one dispatched batch: identical
        accounting to per-request :meth:`resolve`, but one counter/lock
        round-trip per *batch* and a C-loop event extend — the
        per-request form is measurable in the bench's overhead budget.
        ``reqs`` is an iterable of objects with ``rid``/``tenant``/
        ``t_enqueue`` (the serve loop's ``Request``)."""
        reqs = list(reqs)
        n = len(reqs)
        self.c_resolved.inc(n)
        self.g_inflight.dec(n)
        if self.spans:
            evs = [("X", "request", SERVE_REQUEST_PID, r.tenant,
                    r.t_enqueue,
                    t - r.t_enqueue if t > r.t_enqueue else 0.0,
                    r.rid, None) for r in reqs]
            self.h_latency.observe_many([e[5] for e in evs])
            self._appended += n
            self.events.extend(evs)

    def span(self, name: str, t0: float, t1: float,
             args: Optional[dict] = None, tid: int = TID_LOOP) -> None:
        if self.spans:
            self._push(("X", name, SERVE_PID, tid, t0,
                        max(t1 - t0, 0.0), None, args))

    def instant(self, name: str, t: float, args: Optional[dict] = None,
                tid: int = TID_ADMISSION) -> None:
        if self.spans:
            self._push(("i", name, SERVE_PID, tid, t, 0.0, None, args))

    # -------------------------------------------------------------- derived
    def rates(self, since: Optional[dict[str, Any]] = None
              ) -> dict[str, float]:
        """Pad-fraction / defer-rate over the registry's lifetime, or
        over the delta since a prior ``registry.snapshot()``."""
        def delta(name: str) -> float:
            v = float(self.registry.counter(name).value)
            if since is not None:
                v -= float(since.get(name, 0))
            return v

        decisions = delta("asa_serve_decisions_total")
        padded = delta("asa_serve_padded_rows_total")
        requests = delta("asa_serve_requests_total")
        deferrals = delta("asa_serve_deferrals_total")
        dispatched = decisions + padded
        return {
            "pad_fraction": padded / dispatched if dispatched else 0.0,
            "defer_rate": deferrals / requests if requests else 0.0,
        }

    # ------------------------------------------------------------- export
    def chrome_events(self) -> list[dict]:
        """The serve timeline as chrome traceEvents: pid ``SERVE_PID``
        carries the loop-phase spans (tid 0) and admission instants
        (tid 1); pid ``SERVE_REQUEST_PID`` carries one lane per tenant
        with the request lifecycle spans.  Timestamps are µs since the
        ``ServeObs`` epoch."""
        out: list[dict] = [
            {"ph": "M", "pid": SERVE_PID, "name": "process_name",
             "args": {"name": "serve"}},
            {"ph": "M", "pid": SERVE_REQUEST_PID, "name": "process_name",
             "args": {"name": "serve/requests"}},
            {"ph": "M", "pid": SERVE_PID, "name": "serve_obs_meta",
             "args": {"events_kept": len(self.events),
                      "events_dropped": self.events_dropped,
                      "clock": "wall (perf_counter since epoch)"}},
        ]
        for ph, name, pid, tid, t, dur, rid, aux in self.events:
            if rid is not None:  # request lane: aux is an error (or None)
                args: dict = {"rid": rid, "tenant": tid}
                if aux is not None:
                    args["error"] = aux
            else:                # loop lane: aux is the args dict
                args = aux or {}
            ce = {"ph": ph, "pid": pid, "tid": tid, "name": name,
                  "cat": "serve", "ts": (t - self.epoch) * _US,
                  "args": args}
            if ph == "X":
                ce["dur"] = dur * _US
            else:
                ce["s"] = "t"
            out.append(ce)
        return out
