"""Observability for the xsim/RL/bench stack.

* ``obs.trace`` — device-resident per-scenario event ring buffers,
  appended inside the jitted event scan (``trace=None`` elides them).
* ``obs.metrics`` — counters/histograms registry with vmap- and
  shard_map-aware fleet reductions.
* ``obs.export`` — host-side decoding to Chrome trace-event JSON /
  JSONL, schema validation, ``jax.profiler`` wiring.
* ``obs.telemetry`` — the unified (stdlib-only) telemetry schema all
  bench runners emit and ``bench_gate`` consumes.

Deliberately NOT importing submodules here: ``obs.telemetry`` must stay
importable from environments without jax (bench_gate in CI).
"""
