"""Counters/histograms registry over finished sweeps.

``scenario_summary`` reduces ONE final ``ScenarioState`` to a flat dict
of counters (event steps vs budget, drain flag, naive misses/cancels,
backfill hits, over-allocation core-hours, trace event counts) plus a
wait-time histogram over the §4.5 bins. ``sweep_summary`` vmaps it and
reduces the batch axis on device; ``sharded_sweep_summary`` runs the
same reduction *inside* a ``shard_map`` block with a ``psum`` over the
1-D ``scenarios`` mesh, weighting by the padding-validity mask so the
row-0 pad copies never double-count — fleet-level metrics leave the mesh
already reduced to a handful of scalars.

Counter columns are integer sums, so the sharded reduction is exactly
the vmap reduction (integer addition is associative); the few float
columns (``oh_core_hours``, ``steps_frac``) match to reduction order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bins import M_DEFAULT, make_bins
from repro.obs import trace as obtrace
from repro.xsim.state import DONE, QUEUED, ScenarioState

# histogram domain: the same m=53 wait alternatives ASA discretizes over
HIST_BINS = M_DEFAULT


def wait_histogram(s: ScenarioState, bins: jax.Array) -> jax.Array:
    """(M,) i32 counts of observed stage waits, log-nearest-bin bucketed.

    Buckets exactly like ``core.bins.nearest_bin`` (argmin in log space),
    over the workflow rows that actually started.
    """
    valid = s.is_wf & jnp.isfinite(s.start)
    w = jnp.maximum(s.start - s.submit, 1e-9)
    d = jnp.abs(jnp.log(bins)[None, :] - jnp.log(w)[:, None])
    idx = jnp.argmin(d, axis=-1)
    return jnp.zeros(bins.shape[0], jnp.int32).at[idx].add(
        valid.astype(jnp.int32))


def backfill_hits(s: ScenarioState) -> jax.Array:
    """i32 count of FCFS overtakes: job i started while an
    earlier-submitted job j was still waiting (j submitted before i,
    already in the queue at i's start, started later) — each such i is
    one backfill placement the sorted-reservation pass admitted early."""
    started = jnp.isfinite(s.start) & (s.status != QUEUED)
    live = s.cores > 0.0
    overtaken = (live[None, :] & (s.submit[None, :] < s.submit[:, None])
                 & (s.submit[None, :] <= s.start[:, None])
                 & (s.start[None, :] > s.start[:, None]))
    hit = started & live & jnp.any(overtaken, axis=1)
    return jnp.sum(hit.astype(jnp.int32))


def scenario_summary(s: ScenarioState, n_steps: int) -> dict[str, jax.Array]:
    """Per-scenario observability counters (vmap for a fleet).

    ``n_steps`` is the sweep's static step budget (``XSimConfig.n_steps``)
    — ``drained`` means the scenario ran out of events before the budget
    ran out of steps. Trace-derived columns appear only when the state
    carries a trace buffer (``s.trace is None`` elides them statically).
    """
    bins = jnp.asarray(make_bins(HIST_BINS), jnp.float32)
    wf = s.is_wf
    out = {
        "steps": s.steps,
        "step_budget": jnp.int32(n_steps),
        "drained": (s.steps < n_steps).astype(jnp.int32),
        "wf_done": jnp.sum((wf & (s.status == DONE)).astype(jnp.int32)),
        "wf_total": jnp.sum(wf.astype(jnp.int32)),
        "misses": s.misses,
        "cancels": jnp.sum(jnp.isfinite(s.canc_start).astype(jnp.int32)),
        "holds": jnp.sum((s.hold > 0.0).astype(jnp.int32)),
        "oh_core_hours": s.oh_cs / 3600.0,
        "backfill_hits": backfill_hits(s),
        "wait_hist": wait_histogram(s, bins),
    }
    if s.trace is not None:
        C = s.trace.data.shape[-2]
        out["trace_events"] = s.trace.head
        out["trace_dropped"] = jnp.maximum(s.trace.head - C, 0)
        out["trace_overflowed"] = obtrace.overflowed(s.trace).astype(
            jnp.int32)
        kinds = obtrace.column(s.trace, "kind")
        for ev, name in obtrace.EVENT_NAMES.items():
            # surviving (post-overflow) events per kind
            out[f"ev_{name}"] = jnp.sum((kinds == ev).astype(jnp.int32))
    return out


def _reduce(per: dict[str, jax.Array], weights: jax.Array,
            n_steps: int) -> dict[str, jax.Array]:
    """Batch-axis reduction of vmapped summaries (weights mask pad rows)."""
    out = {}
    for k, v in per.items():
        w = weights.reshape((-1,) + (1,) * (v.ndim - 1)).astype(v.dtype)
        out[k] = jnp.sum(v * w, axis=0)
    n = jnp.sum(weights.astype(jnp.float32))
    out["n_scenarios"] = n.astype(jnp.int32)
    out["step_budget"] = jnp.int32(n_steps)
    out["drain_frac"] = out.pop("drained").astype(jnp.float32) \
        / jnp.maximum(n, 1.0)
    out["steps_frac"] = out["steps"].astype(jnp.float32) \
        / jnp.maximum(n * n_steps, 1.0)
    return out


@functools.partial(jax.jit, static_argnames=("n_steps",))
def sweep_summary(final: ScenarioState, *, n_steps: int
                  ) -> dict[str, jax.Array]:
    """Fleet-level summary of a batched final state (single device)."""
    per = jax.vmap(lambda s: scenario_summary(s, n_steps))(final)
    B = per["steps"].shape[0]
    return _reduce(per, jnp.ones(B, jnp.int32), n_steps)


def sharded_sweep_summary(final: ScenarioState, mesh, *, n_steps: int
                          ) -> dict[str, jax.Array]:
    """``sweep_summary`` without gathering the states: each device
    reduces its own block of final scenarios and a ``psum`` over the
    ``scenarios`` mesh axis finishes the job — only the summary scalars
    (and one (53,) histogram) ever leave the mesh. Pad rows (copies of
    scenario 0, see ``parallel.fleet.pad_batch``) are zero-weighted so
    they never double-count. Counter columns match ``sweep_summary``
    exactly (integer sums); float columns to reduction order."""
    from jax.experimental.shard_map import shard_map

    from repro.parallel import fleet as pfleet

    n_shards = mesh.shape[pfleet.SCENARIO_AXIS]
    padded, mask = pfleet.pad_batch(final, n_shards)

    def block(shard: ScenarioState, m):
        per = jax.vmap(lambda s: scenario_summary(s, n_steps))(shard)
        local = _reduce(per, m, n_steps)
        # undo _reduce's local normalizations, psum the raw sums, redo
        n_loc = local.pop("n_scenarios")
        drained = local.pop("drain_frac") * jnp.maximum(
            n_loc.astype(jnp.float32), 1.0)
        local.pop("steps_frac")
        summed = jax.lax.psum(
            {**local, "n_scenarios": n_loc, "drained": drained},
            pfleet.SCENARIO_AXIS)
        n = summed.pop("n_scenarios").astype(jnp.float32)
        summed["n_scenarios"] = n.astype(jnp.int32)
        summed["step_budget"] = jnp.int32(n_steps)
        summed["drain_frac"] = summed.pop("drained") / jnp.maximum(n, 1.0)
        summed["steps_frac"] = summed["steps"].astype(jnp.float32) \
            / jnp.maximum(n * n_steps, 1.0)
        return summed

    spec = pfleet.shard_spec()
    fn = shard_map(block, mesh=mesh,
                   in_specs=(spec, spec),
                   out_specs=pfleet.replicated_spec(), check_rep=False)
    return jax.jit(fn)(padded, mask)


def replay_chain_waits(s: ScenarioState
                       ) -> tuple[np.ndarray, np.ndarray, np.float32]:
    """Reconstruct the ASA-chain perceived stage waits from the trace.

    Replays ONE scenario's decoded event ring (submit/start/cancel
    order) through the same f32 recurrences ``events._start_hook`` and
    ``compare.metrics`` use — predecessor logical end
    ``start + hold + duration``, naive hold-vs-cancel rule, then the
    settled-timeline chain ``le_y = max(start_y + hold_y, le_{y-1}) +
    t_y`` — using only trace timestamps plus the static job table
    (durations, stage chain). Returns ``(pwt, valid, twt)``: per-stage
    perceived waits, their validity mask, and their f32 running sum —
    bit-equal to ``compare.metrics(s)["twt_s"]`` for ASA-like scenarios
    (the differential test in tests/test_obs.py pins this on the 12
    mirrored QueueSim scenarios).
    """
    from repro.sched.strategies import NAIVE_IDLE_THRESHOLD_S
    from repro.xsim.state import ASA_NAIVE, RL

    if s.trace is None:
        raise ValueError("scenario carries no trace buffer")
    events, meta = obtrace.decode(s.trace)
    if meta["dropped"]:
        raise ValueError(f"ring overflowed ({meta['dropped']} events "
                         "dropped); waits are not reconstructible")
    # the miss machinery only runs for dependency-free policies
    # (events._naive_like); other policies take every start as settled
    naive_like = int(np.asarray(s.policy)) in (ASA_NAIVE, RL)
    wf_rows = np.asarray(s.wf_rows)
    dur = np.asarray(s.duration, np.float32)
    S = wf_rows.shape[0]
    stage_of = {int(r): y for y, r in enumerate(wf_rows) if r >= 0}
    f32 = np.float32
    start = np.full(S, np.inf, f32)
    hold = np.zeros(S, f32)
    canc = np.full(S, np.inf, f32)
    cancelled = np.zeros(S, bool)
    submit0 = f32(np.nan)
    thr = f32(NAIVE_IDLE_THRESHOLD_S)

    for i in range(len(events["kind"])):
        r = int(events["job"][i])
        if r not in stage_of:
            continue
        k = int(events["kind"][i])
        y = stage_of[r]
        t = f32(events["t"][i])
        if k == obtrace.EV_SUBMIT and y == 0 and np.isnan(submit0):
            submit0 = t
        elif k == obtrace.EV_START:
            if y == 0 or not naive_like:
                start[y] = t
                continue
            yp, rp = y - 1, int(wf_rows[y - 1])
            # _start_hook's prev_logical, f32 op for op
            if np.isfinite(start[yp]):
                prev_logical = f32(f32(start[yp] + hold[yp]) + dur[rp])
            elif cancelled[yp] and np.isfinite(canc[yp]):
                prev_logical = f32(canc[yp] + dur[rp])
            else:
                prev_logical = f32(np.inf)
            early = f32(prev_logical - t)
            if early > thr:         # long gap: cancelled at this instant
                cancelled[y] = True  # (EV_CANCEL follows in the ring)
                canc[y] = t
            else:
                start[y] = t
                cancelled[y] = False
                if early > f32(0.0):
                    hold[y] = early

    # compare.metrics' settled-timeline chain, f32 op for op
    le = f32(-np.inf)
    twt = f32(0.0)
    pwt = np.zeros(S, f32)
    valid = np.zeros(S, bool)
    for y in range(S):
        r = int(wf_rows[y])
        if r < 0 or not np.isfinite(start[y]):
            continue
        valid[y] = True
        start_l = f32(start[y] + hold[y])
        if y == 0:
            pwt[y] = f32(start[y] - submit0)
            le = f32(start_l + dur[r])
        else:
            pwt[y] = (f32(0.0) if np.isneginf(le)
                      else np.maximum(f32(start[y] - le), f32(0.0)))
            le = f32(np.maximum(start_l, le) + dur[r])
        twt = f32(twt + pwt[y])
    return pwt, valid, twt


def to_host(summary: dict[str, jax.Array]) -> dict:
    """JSON-safe python view of a (fleet or per-scenario) summary."""
    out = {}
    for k, v in summary.items():
        a = np.asarray(v)
        if a.ndim == 0:
            out[k] = a.item()
        else:
            out[k] = a.tolist()
    return out
