"""Host-side trace decoding → Chrome trace-event JSON / JSONL.

``chrome_trace`` turns a swept batch's ring buffers into the Chrome
trace-event format (one *process* track per scenario, one *thread* per
job row): matched start→finish pairs become complete-event spans
(``ph: "X"``), submits/cancels/resubmits become instants (``ph: "i"``),
and per-scenario metadata carries the ring accounting (events ever
appended, kept, dropped) plus ``ScenarioState.steps`` so a trace can be
cross-checked against the state it came from. Open the file directly in
Perfetto / ``chrome://tracing``.

``jsonl_events`` is the structured-log view: one JSON object per decoded
event, ready for ad-hoc ``jq``/pandas work.

``profile_session`` wraps ``jax.profiler.start_trace``/``stop_trace``
(compile-vs-steady attribution: annotate the first rep with
``annotate("compile")`` and the rest with ``annotate("steady")``).

Run ``python -m repro.obs.export --validate f.json ...`` to check a
Chrome trace or telemetry file against its schema (CI's trace-smoke leg).
"""

from __future__ import annotations

import contextlib
import json
from typing import Any

import numpy as np

from repro.obs import trace as obtrace
from repro.obs.trace import (EV_CANCEL, EV_FINISH, EV_KILL, EV_RESUBMIT,
                             EV_START, EV_SUBMIT, EVENT_NAMES)

_US = 1_000_000.0  # chrome ts unit: microseconds; sim time is seconds


def _scenario_events(events: dict[str, np.ndarray], meta: dict,
                     pid: int, final_t: float) -> list[dict]:
    """One scenario's decoded ring → chrome traceEvents (pid = scenario).

    Spans pair each job's START with its next FINISH; a START with no
    FINISH (still running / budget truncation) closes at the scenario's
    final sim time so Perfetto shows the dangling allocation.
    """
    out: list[dict] = []
    open_start: dict[int, tuple[float, int, float]] = {}  # job → (t, stage, cores)
    for i in range(len(events["kind"])):
        kind = int(events["kind"][i])
        t = float(events["t"][i])
        job = int(events["job"][i])
        stage = int(events["stage"][i])
        cores = float(events["cores"][i])
        args = {"job": job, "stage": stage, "cores": cores,
                "step": int(events["step"][i])}
        if kind == EV_START:
            open_start[job] = (t, stage, cores)
        elif kind == EV_FINISH and job in open_start:
            t0, st0, c0 = open_start.pop(job)
            out.append({"ph": "X", "pid": pid, "tid": job,
                        "name": f"run j{job}" + (f" s{st0}" if st0 >= 0
                                                 else ""),
                        "cat": "run", "ts": t0 * _US,
                        "dur": max(t - t0, 0.0) * _US,
                        "args": {**args, "stage": st0, "cores": c0}})
        elif kind in (EV_SUBMIT, EV_CANCEL, EV_RESUBMIT, EV_KILL):
            if kind == EV_CANCEL:
                open_start.pop(job, None)  # cancelled at its start instant
            elif kind == EV_KILL and job in open_start:
                # killed mid-run by a node failure: close the open
                # allocation span at the kill instant (the lost attempt)
                t0, st0, c0 = open_start.pop(job)
                out.append({"ph": "X", "pid": pid, "tid": job,
                            "name": f"run j{job} (killed)", "cat": "run",
                            "ts": t0 * _US,
                            "dur": max(t - t0, 0.0) * _US,
                            "args": {**args, "stage": st0, "cores": c0}})
            out.append({"ph": "i", "pid": pid, "tid": job, "s": "t",
                        "name": EVENT_NAMES[kind], "cat": EVENT_NAMES[kind],
                        "ts": t * _US, "args": args})
        elif kind == EV_FINISH:  # finish whose start was overwritten
            out.append({"ph": "i", "pid": pid, "tid": job, "s": "t",
                        "name": "finish", "cat": "finish", "ts": t * _US,
                        "args": args})
    for job, (t0, st0, c0) in sorted(open_start.items()):
        out.append({"ph": "X", "pid": pid, "tid": job,
                    "name": f"run j{job} (open)", "cat": "run",
                    "ts": t0 * _US, "dur": max(final_t - t0, 0.0) * _US,
                    "args": {"job": job, "stage": st0, "cores": c0,
                             "open": True}})
    return out


def chrome_trace(final, labels: list[dict] | None = None) -> dict[str, Any]:
    """A batched final ``ScenarioState`` (with trace) → chrome trace dict.

    ``labels`` (e.g. ``ScenarioGrid.labels``) name each scenario's
    process track; scenario accounting (ring totals + ``steps``) rides in
    per-scenario ``trace_meta`` metadata events.
    """
    if final.trace is None:
        raise ValueError("final state carries no trace buffer; build the "
                         "grid with trace_capacity > 0 (XSimConfig) or "
                         "state.freeze(trace_capacity=...)")
    decoded = obtrace.decode_batch(final.trace)
    steps = np.asarray(final.steps)
    final_t = np.asarray(final.t)
    te: list[dict] = []
    for pid, (events, meta) in enumerate(decoded):
        name = f"scenario {pid}"
        if labels is not None:
            lab = labels[pid]
            name = (f"{lab.get('center', '?')}/{lab.get('workflow', '?')}/"
                    f"{lab.get('strategy', '?')}#{lab.get('seed', pid)}")
        te.append({"ph": "M", "pid": pid, "name": "process_name",
                   "args": {"name": name}})
        te.append({"ph": "M", "pid": pid, "name": "trace_meta",
                   "args": {**meta, "steps": int(steps[pid])}})
        te.extend(_scenario_events(events, meta, pid, float(final_t[pid])))
    return {"traceEvents": te, "displayTimeUnit": "ms",
            "otherData": {"format": "repro.obs.chrome_trace", "version": 1,
                          "n_scenarios": len(decoded)}}


def merged_chrome_trace(final=None, labels: list[dict] | None = None,
                        serve=None) -> dict[str, Any]:
    """One Chrome trace interleaving the device event rings with the
    serve-side request-lifecycle timeline.

    ``final`` is a batched final ``ScenarioState`` carrying a trace (or
    None for a serve-only file); ``serve`` is a
    ``repro.obs.serve_obs.ServeObs``.  The serve rows land on the
    reserved pids ``serve_obs.SERVE_PID``/``SERVE_REQUEST_PID`` —
    asserted to sit above every scenario pid, so one file never
    collides ids between the two sources.  Scenario rows tick in
    *simulated* seconds, serve rows in *wall-clock* seconds since the
    ``ServeObs`` epoch; the merged file interleaves the clocks as
    separate process tracks, it does not align them.
    """
    from repro.obs import serve_obs as sobs

    if final is None and serve is None:
        raise ValueError("merged_chrome_trace needs a traced final "
                         "state, a ServeObs, or both")
    if final is not None:
        out = chrome_trace(final, labels)
    else:
        out = {"traceEvents": [], "displayTimeUnit": "ms",
               "otherData": {"format": "repro.obs.chrome_trace",
                             "version": 1, "n_scenarios": 0}}
    if serve is not None:
        n = out["otherData"]["n_scenarios"]
        if n >= sobs.SERVE_PID:
            raise ValueError(
                f"{n} scenario pids reach the reserved serve pid "
                f"{sobs.SERVE_PID}; shrink the fleet or move SERVE_PID")
        out["traceEvents"].extend(serve.chrome_events())
        out["otherData"]["serve_pid"] = sobs.SERVE_PID
        out["otherData"]["serve_request_pid"] = sobs.SERVE_REQUEST_PID
    return out


def write_merged_trace(path: str, final=None, labels=None,
                       serve=None) -> dict[str, Any]:
    """Export + write the merged trace; returns a small accounting dict
    for the telemetry record (event counts per source + the path)."""
    merged = merged_chrome_trace(final, labels, serve)
    with open(path, "w") as f:
        json.dump(merged, f)
    meta: dict[str, Any] = {"path": path,
                            "n_scenarios": merged["otherData"]
                            ["n_scenarios"],
                            "events_total": len(merged["traceEvents"])}
    if serve is not None:
        meta["serve_events_kept"] = len(serve.events)
        meta["serve_events_dropped"] = serve.events_dropped
    return meta


def jsonl_events(final, labels: list[dict] | None = None) -> list[dict]:
    """Structured-log view: one dict per decoded event, all scenarios."""
    if final.trace is None:
        raise ValueError("final state carries no trace buffer")
    rows: list[dict] = []
    for sid, (events, meta) in enumerate(obtrace.decode_batch(final.trace)):
        lab = labels[sid] if labels is not None else {}
        for i in range(len(events["kind"])):
            rows.append({
                "scenario": sid,
                "event": EVENT_NAMES.get(int(events["kind"][i]), "?"),
                "t": float(events["t"][i]),
                "job": int(events["job"][i]),
                "stage": int(events["stage"][i]),
                "cores": float(events["cores"][i]),
                "policy": int(events["policy"][i]),
                "step": int(events["step"][i]),
                **{k: lab[k] for k in ("center", "workflow", "strategy")
                   if k in lab},
            })
    return rows


def trace_meta(final) -> dict[str, Any]:
    """Telemetry ``trace`` section: fleet-level ring accounting."""
    if final.trace is None:
        return None
    head = np.asarray(final.trace.head)
    C = int(final.trace.data.shape[-2])
    return {"capacity": C,
            "n_scenarios": int(head.shape[0]) if head.ndim else 1,
            "events_total": int(head.sum()),
            "events_dropped": int(np.maximum(head - C, 0).sum()),
            "scenarios_overflowed": int((head > C).sum())}


def write_chrome_trace(path: str, final, labels=None) -> dict[str, Any]:
    """Export + write a chrome trace; returns its ``trace_meta`` section
    (with the output ``path`` added) for the telemetry record."""
    with open(path, "w") as f:
        json.dump(chrome_trace(final, labels), f)
    meta = trace_meta(final)
    meta["path"] = path
    return meta


def write_jsonl(path: str, final, labels=None) -> int:
    """Write the JSONL view; returns the number of event rows."""
    rows = jsonl_events(final, labels)
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return len(rows)


# ------------------------------------------------- jax.profiler attribution


@contextlib.contextmanager
def profile_session(logdir: str | None):
    """``jax.profiler`` start/stop around a bench section (None = off)."""
    if logdir is None:
        yield
        return
    import jax

    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def annotate(name: str):
    """Named profiler span (e.g. "compile" for rep 0, "steady" after)."""
    import jax

    return jax.profiler.TraceAnnotation(name)


# ------------------------------------------------------- schema validation


def validate_chrome(obj: Any) -> list[str]:
    """Structural check of an exported chrome trace (empty ⇒ valid)."""
    errs: list[str] = []
    if not isinstance(obj, dict):
        return [f"trace is {type(obj).__name__}, expected object"]
    te = obj.get("traceEvents")
    if not isinstance(te, list):
        return [f"traceEvents is {type(te).__name__}, expected list"]
    for i, ev in enumerate(te):
        if not isinstance(ev, dict):
            errs.append(f"traceEvents[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            errs.append(f"traceEvents[{i}] has ph={ph!r}")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"traceEvents[{i}] ({ev.get('name')}) missing ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"traceEvents[{i}] ({ev.get('name')}) missing dur")
        if "pid" not in ev:
            errs.append(f"traceEvents[{i}] missing pid")
        if len(errs) > 20:
            errs.append("... (further errors suppressed)")
            break
    return errs


def validate_file(path: str) -> list[str]:
    """Validate one JSON file as telemetry or a chrome trace (by sniff)."""
    from repro.obs import telemetry

    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if telemetry.is_telemetry(obj):
        msgs = telemetry.validate(obj)
        for w in msgs:
            if telemetry.is_warning(w):
                print(f"{path}: {w}")
        errs = telemetry.hard_errors(msgs)
    elif isinstance(obj, dict) and "traceEvents" in obj:
        errs = validate_chrome(obj)
    else:
        errs = ["neither a telemetry record (telemetry_version) nor a "
                "chrome trace (traceEvents)"]
    return [f"{path}: {e}" for e in errs]


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate exported telemetry / chrome-trace JSON")
    ap.add_argument("--validate", nargs="+", metavar="FILE", required=True)
    args = ap.parse_args(argv)
    failures = []
    for path in args.validate:
        errs = validate_file(path)
        failures.extend(errs)
        print(f"{'FAIL' if errs else 'ok':4s} {path}")
    for e in failures:
        print(f"  {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
