"""The unified telemetry schema every bench/train runner emits.

One JSON object per run leg, written by ``benchmarks/xsim_throughput.py``,
``benchmarks/run.py`` and ``benchmarks/rl_train.py``, and consumed by
``benchmarks/bench_gate.py`` — which runs from a bare checkout *without
jax*, so this module is **stdlib-only** (importing it must never pull
``repro.obs.trace``/``metrics``/``export`` or anything that imports jax).

Schema v1 (a "record"):

    {
      "telemetry_version": 1,
      "kind": "xsim_throughput" | "xsim_strategies" | "rl_train"
              | "serve_latency" | "serve_metrics" | "serve_chaos",
      "run": {...},        # runner identity: label/config/flags
      "profile": {...},    # timing: compile_s, steady_s, scenarios_per_sec,
                           #         us_per_scenario, (trace_overhead_frac)
      "metrics": {...},    # obs.metrics fleet summary (counters/histograms)
      "trace": {...}|null, # trace meta: capacity/events/dropped/path
    }

``kind`` determines which sections are required (REQUIRED_SECTIONS).
Unknown extra keys are allowed — the version only bumps when an existing
field changes meaning or a required one disappears.  An unknown ``kind``
is a *warn-level* validation entry, not a hard failure (forward
compatibility: a newer runner's record still merges; see
``is_warning``/``hard_errors``).
"""

from __future__ import annotations

from typing import Any

TELEMETRY_VERSION = 1

KINDS = ("xsim_throughput", "xsim_strategies", "rl_train",
         "serve_latency", "serve_metrics", "serve_chaos")

# sections a record of each kind must carry ("trace" may be None but the
# key itself must exist — it says "tracing was off", not "schema unknown")
_SECTIONS = ("run", "profile", "metrics", "trace")
REQUIRED_SECTIONS: dict[str, tuple[str, ...]] = {
    "xsim_throughput": _SECTIONS,
    "xsim_strategies": _SECTIONS,
    "rl_train": _SECTIONS,
    "serve_latency": _SECTIONS,
    # registry snapshot of the serving loop (benchmarks/serve_latency.py
    # --metrics-json): profile carries the batching-health rates the
    # gate consumes, metrics the raw obs.registry snapshot
    "serve_metrics": _SECTIONS,
    # chaos soak (benchmarks/serve_chaos.py): profile carries fault
    # recovery percentiles + the zero-hung-futures invariant the gate
    # enforces, metrics the final obs.registry snapshot
    "serve_chaos": _SECTIONS,
}

WARNING_PREFIX = "warning: "

# profile keys bench_gate gates on for throughput legs
PROFILE_REQUIRED = ("scenarios_per_sec", "us_per_scenario")

# profile keys bench_gate gates on for serving legs (benchmarks/
# serve_latency.py): decision latency percentiles + sustained rate
SERVE_PROFILE_REQUIRED = ("p50_ms", "p99_ms", "decisions_per_sec")

# profile keys a serve_metrics record must carry (batching health:
# fraction of dispatched rows that were padding, fraction of requests
# the dedup batcher deferred)
SERVE_METRICS_PROFILE_REQUIRED = ("pad_fraction", "defer_rate")

# profile keys a serve_chaos record must carry: p99 seconds from fault
# injection to next successful resolve, count of futures never resolved
# (the invariant: must be 0), and shed requests / submitted requests
CHAOS_PROFILE_REQUIRED = ("recovery_p99_ms", "hung_futures", "shed_rate")


def is_warning(msg: str) -> bool:
    """True for warn-level validation entries (unknown ``kind`` above
    all) — consumers list them but must not hard-fail on them."""
    return msg.startswith(WARNING_PREFIX)


def hard_errors(msgs: list[str]) -> list[str]:
    """The subset of :func:`validate` entries that invalidate a record."""
    return [m for m in msgs if not is_warning(m)]


def record(kind: str, *, run: dict[str, Any], profile: dict[str, Any],
           metrics: dict[str, Any], trace: dict[str, Any] | None = None,
           ) -> dict[str, Any]:
    """Assemble a schema-v1 telemetry record (validates on the way out)."""
    rec = {"telemetry_version": TELEMETRY_VERSION, "kind": kind,
           "run": run, "profile": profile, "metrics": metrics,
           "trace": trace}
    errs = hard_errors(validate(rec))
    if errs:
        raise ValueError("invalid telemetry record: " + "; ".join(errs))
    return rec


def is_telemetry(obj: Any) -> bool:
    """Loose sniff: does this JSON object claim to be a telemetry record?"""
    return isinstance(obj, dict) and "telemetry_version" in obj


def validate(rec: Any) -> list[str]:
    """Return a list of schema violations (empty ⇒ valid).

    Collects every problem instead of raising on the first so CI's
    trace-smoke leg can print them all at once.  An unknown ``kind`` is
    a **warn-level** entry (``warning: ...`` prefix — schema v1 allows
    forward-compatible kinds; the standard four sections are still
    required), never a hard failure; split the two with
    :func:`hard_errors` / :func:`is_warning`.
    """
    errs: list[str] = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, expected object"]
    ver = rec.get("telemetry_version")
    if ver != TELEMETRY_VERSION:
        errs.append(f"telemetry_version is {ver!r}, "
                    f"expected {TELEMETRY_VERSION}")
    kind = rec.get("kind")
    if not isinstance(kind, str) or not kind:
        errs.append(f"kind is {kind!r}, expected a non-empty string "
                    f"(known kinds: {KINDS})")
        return errs
    if kind not in KINDS:
        errs.append(f"{WARNING_PREFIX}kind {kind!r} is not a known kind "
                    f"{KINDS}; validating the standard sections only")
    for sec in REQUIRED_SECTIONS.get(kind, _SECTIONS):
        if sec not in rec:
            errs.append(f"missing section {sec!r}")
        elif sec != "trace" and not isinstance(rec[sec], dict):
            errs.append(f"section {sec!r} is "
                        f"{type(rec[sec]).__name__}, expected object")
    tr = rec.get("trace")
    if tr is not None and not isinstance(tr, dict):
        errs.append(f"section 'trace' is {type(tr).__name__}, "
                    "expected object or null")
    prof = rec.get("profile")
    if kind in ("xsim_throughput",) and isinstance(prof, dict):
        for k in PROFILE_REQUIRED:
            if k not in prof:
                errs.append(f"profile missing {k!r}")
    if kind == "serve_latency" and isinstance(prof, dict):
        for k in SERVE_PROFILE_REQUIRED:
            if k not in prof:
                errs.append(f"profile missing {k!r}")
    if kind == "serve_metrics" and isinstance(prof, dict):
        for k in SERVE_METRICS_PROFILE_REQUIRED:
            if k not in prof:
                errs.append(f"profile missing {k!r}")
    if kind == "serve_chaos" and isinstance(prof, dict):
        for k in CHAOS_PROFILE_REQUIRED:
            if k not in prof:
                errs.append(f"profile missing {k!r}")
    return errs


def throughput_leg(rec: dict[str, Any]) -> dict[str, Any]:
    """Flatten a throughput record into bench_gate's leg view.

    Returns ``{"freed_mode", "n_shards", "traced", "scenarios_per_sec",
    "us_per_scenario", ...profile}`` — raises KeyError-free ValueError
    naming what is missing (bench_gate surfaces it per leg).
    Warn-level entries (unknown kinds) never raise.
    """
    errs = hard_errors(validate(rec))
    if errs:
        raise ValueError("; ".join(errs))
    run, prof = rec["run"], rec["profile"]
    leg = dict(prof)
    leg["freed_mode"] = run.get("freed_mode", "ref")
    leg["n_shards"] = run.get("n_shards")
    leg["traced"] = bool(run.get("traced", False))
    leg["label"] = run.get("label", "")
    return leg


def serve_leg(rec: dict[str, Any]) -> dict[str, Any]:
    """Flatten a serve_latency record into bench_gate's leg view:
    the gated profile (p50/p99 decision latency, decisions/sec, plus the
    batching-health rates pad_fraction/defer_rate when present) and the
    run identity (mode, shards, tenants, batch size).  Raises ValueError
    naming what is missing, like ``throughput_leg``."""
    errs = hard_errors(validate(rec))
    if errs:
        raise ValueError("; ".join(errs))
    if rec.get("kind") != "serve_latency":
        raise ValueError(f"kind is {rec.get('kind')!r}, "
                         "expected 'serve_latency'")
    run, prof = rec["run"], rec["profile"]
    leg = dict(prof)
    # batching health may ride in either section (the bench emits it in
    # profile; older records carried it in metrics) — flatten both
    met = rec.get("metrics") or {}
    for k in ("pad_fraction", "defer_rate"):
        if k not in leg and k in met:
            leg[k] = met[k]
    leg["n_shards"] = run.get("n_shards")
    leg["label"] = run.get("label", "")
    leg["mode"] = run.get("mode", "open")
    for k in ("n_tenants", "n_slots", "batch_size", "backend"):
        if k in run:
            leg[k] = run[k]
    return leg


def serve_metrics_leg(rec: dict[str, Any]) -> dict[str, Any]:
    """Flatten a serve_metrics record (the serving loop's registry
    snapshot): the profile rates plus a handful of headline counters
    from the raw registry snapshot in ``metrics``."""
    errs = hard_errors(validate(rec))
    if errs:
        raise ValueError("; ".join(errs))
    if rec.get("kind") != "serve_metrics":
        raise ValueError(f"kind is {rec.get('kind')!r}, "
                         "expected 'serve_metrics'")
    run, prof = rec["run"], rec["profile"]
    leg = dict(prof)
    leg["n_shards"] = run.get("n_shards")
    leg["label"] = run.get("label", "")
    snap = rec.get("metrics") or {}
    for k in ("asa_serve_requests_total", "asa_serve_resolved_total",
              "asa_serve_failed_total", "asa_serve_deferrals_total",
              "asa_serve_evictions_total",
              "asa_serve_evicted_requests_total"):
        if k in snap:
            leg[k] = snap[k]
    return leg


def serve_chaos_leg(rec: dict[str, Any]) -> dict[str, Any]:
    """Flatten a serve_chaos record (the chaos soak) into bench_gate's
    leg view: the gated profile (recovery_p99_ms, hung_futures,
    shed_rate, plus whatever else the soak reports) and the headline
    fault/recovery counters from the final registry snapshot."""
    errs = hard_errors(validate(rec))
    if errs:
        raise ValueError("; ".join(errs))
    if rec.get("kind") != "serve_chaos":
        raise ValueError(f"kind is {rec.get('kind')!r}, "
                         "expected 'serve_chaos'")
    run, prof = rec["run"], rec["profile"]
    leg = dict(prof)
    leg["label"] = run.get("label", "")
    for k in ("seed", "n_tenants", "max_queue", "duration_s"):
        if k in run:
            leg[k] = run[k]
    snap = rec.get("metrics") or {}
    for k in ("asa_serve_step_errors_total", "asa_serve_crashes_total",
              "asa_serve_restarts_total", "asa_serve_shed_total",
              "asa_serve_lease_evictions_total",
              "asa_serve_checkpoint_failures_total",
              "asa_serve_stop_drained_total"):
        if k in snap:
            leg[k] = snap[k]
    return leg
