"""Host-side live-metrics registry: counters, gauges, fixed-bucket
histograms.

``obs.metrics`` aggregates *device-side* state after a sweep finishes;
this module is its host-side dual for long-running processes (the
serving loop above all): metrics that are **mutated on the hot path and
scraped while the process runs**.  Design constraints, in order:

1. **Low overhead.**  One uncontended ``threading.Lock`` acquire per
   mutation (~100 ns in CPython) — never a lock per scrape *held across
   the registry*: scrapes snapshot metric-by-metric, so a slow scraper
   cannot stall the serve loop.  The serving bench reports the measured
   end-to-end cost as ``profile.serve_obs_overhead_frac`` (budget: ≤ 5%
   decisions/sec).
2. **Stdlib-only**, like ``obs.telemetry``: importing this module must
   never pull jax, so CI's gate-side tooling and bare-checkout scripts
   can read snapshots and render Prometheus text without a jax install.
3. **Fixed buckets.**  Histograms use the same style as
   ``obs.metrics``'s §4.5 wait histograms: a geometric (log-uniform)
   bucket ladder fixed at construction (53 bins by default, mirroring
   the paper's m = 53 wait alternatives), so snapshots from different
   processes/runs are always mergeable bucket-for-bucket.

Exposition formats:

* ``Registry.prometheus_text()`` — the Prometheus text exposition
  format (``# HELP``/``# TYPE`` + cumulative ``_bucket{le=...}`` rows),
  served by ``serve.loop.ASAServer`` under ``GET /metrics``;
* ``Registry.snapshot()`` — a flat JSON-safe dict (counters as ints,
  gauges as floats, histograms as ``{buckets, counts, sum, count}``),
  served under ``GET /metrics.json`` and embedded in the
  ``serve_metrics`` telemetry record ``bench_gate`` consumes.

Counters are monotone by contract (``inc`` rejects negative deltas), so
two consecutive scrapes of the same process must never show a counter
decreasing — CI's scrape smoke asserts exactly that.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Any, Optional

# mirror of core.bins.M_DEFAULT without importing jax-adjacent modules
M_BUCKETS_DEFAULT = 53


def geometric_buckets(lo: float, hi: float,
                      n: int = M_BUCKETS_DEFAULT) -> tuple[float, ...]:
    """``n`` log-uniform bucket upper bounds spanning [lo, hi] — the same
    ladder shape as ``core.bins.make_bins`` builds for the §4.5 wait
    alternatives (geometric from the smallest to the largest bucket)."""
    if not (0.0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if n < 2:
        raise ValueError(f"need n >= 2 buckets, got {n}")
    r = math.log(hi / lo) / (n - 1)
    return tuple(lo * math.exp(r * i) for i in range(n))


# default latency ladder: 100 µs .. 100 s, 53 geometric buckets — wide
# enough for a jitted decision batch (ms) and a cold compile (tens of s)
LATENCY_BUCKETS_S = geometric_buckets(1e-4, 100.0)

# default fraction ladder for pad-fraction/fill-style observations
FRACTION_BUCKETS = tuple((i + 1) / 20.0 for i in range(20))


class _Metric:
    """Shared bookkeeping: name, help text, one cheap lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotone event count (float deltas allowed, never negative)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int | float:
        return self._value

    def snapshot(self) -> int | float:
        return self._value


class Gauge(_Metric):
    """A value that goes both ways (queue depth, tenants, free slots).

    A gauge can instead be **fn-backed** (``set_fn``): the value is
    computed by a callback at snapshot/scrape time rather than pushed by
    the hot path — right for derived freshness signals like
    last-batch-age, where the interesting value keeps changing while the
    loop is *not* running.  The callback must be cheap and must never
    raise; a raising callback reads as 0.0 rather than killing a scrape.
    """

    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._value = 0.0
        self._fn = None

    def set(self, v: float) -> None:
        with self._lock:
            self._fn = None
            self._value = v

    def set_fn(self, fn) -> None:
        """Back the gauge with ``fn() -> float``, evaluated per snapshot
        (``set`` reverts to a plain pushed gauge)."""
        with self._lock:
            self._fn = fn

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        return self.snapshot()

    def snapshot(self) -> float:
        fn = self._fn
        if fn is not None:
            try:
                return float(fn())
            except Exception:
                return 0.0
        return self._value


class Histogram(_Metric):
    """Fixed-bucket histogram (bucket uppers set at construction).

    ``observe`` bisects the (sorted) upper-bound ladder; values above
    the last bound land in the implicit +Inf overflow bucket.  The
    stored counts are per-bucket (not cumulative); the Prometheus
    exposition cumulates on the way out, as the format requires.
    """

    kind = "histogram"

    def __init__(self, name: str, buckets: tuple[float, ...],
                 help: str = "") -> None:
        super().__init__(name, help)
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name}: buckets must be a "
                             f"non-empty ascending sequence")
        self.buckets = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.buckets) + 1)  # +1: +Inf overflow
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def observe_many(self, values) -> None:
        """Bulk observe under ONE lock acquisition — the serving loop
        resolves a whole batch at once, and a lock round-trip per
        request is measurable at full decision rate."""
        b = self.buckets
        with self._lock:
            n = 0
            for v in values:
                self._counts[bisect.bisect_left(b, v)] += 1
                self._sum += v
                n += 1
            self._count += n

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            s, c = self._sum, self._count
        return {"buckets": list(self.buckets), "counts": counts,
                "sum": s, "count": c}


class Registry:
    """A named collection of metrics with one-call exposition.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent by
    name, TypeError on a kind clash), so instrumentation sites never
    need registration order.  All names should share a prefix
    (``asa_serve_`` for the serving loop) so scrapes from different
    subsystems can be federated.
    """

    def __init__(self) -> None:
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, *args) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, help=help) if args else \
                    cls(name, help=help)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = LATENCY_BUCKETS_S,
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, help, buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # ---------------------------------------------------------- exposition
    def snapshot(self) -> dict[str, Any]:
        """Flat JSON-safe view: one key per metric (histograms nest)."""
        out: dict[str, Any] = {}
        for name in sorted(self._metrics):
            out[name] = self._metrics[name].snapshot()
        return out

    def json_line(self, **extra: Any) -> str:
        """One JSONL snapshot line (``extra`` merges in, e.g. a ts)."""
        return json.dumps({**extra, **self.snapshot()},
                          separators=(",", ":"))

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                snap = m.snapshot()
                cum = 0
                for ub, c in zip(snap["buckets"], snap["counts"]):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{ub:.6g}"}} {cum}')
                cum += snap["counts"][-1]
                lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{name}_sum {snap['sum']:.9g}")
                lines.append(f"{name}_count {snap['count']}")
            else:
                v = m.snapshot()
                lines.append(f"{name} {v:.9g}" if isinstance(v, float)
                             else f"{name} {v}")
        return "\n".join(lines) + "\n"
