"""Device-resident, fixed-capacity per-scenario event ring buffer.

A ``TraceBuffer`` records scheduling events (admissions, starts,
completions, naive cancels/resubmits) *inside* the jitted event scan:
the whole buffer is one fixed ``(capacity, NF)`` f32 matrix plus one
monotone ``head`` counter, so it rides ``ScenarioState`` through
``lax.scan`` / ``vmap`` / ``shard_map`` like any other job-table
column. ``trace=None`` on the state statically elides every append —
the disabled path is the pre-observability program, bit for bit
(pinned by tests/test_obs.py).

Ring semantics — a *sliding window*, not a modulo ring: the buffer
always holds the newest ``min(head, capacity)`` events, oldest first,
right-aligned (rows ``[capacity - kept, capacity)``); rows in front of
that are still the zeros ``init`` wrote (kind 0 = empty). An append
compacts its masked lanes to a dense, lane-ordered prefix (cumsum +
``searchsorted`` + gather — deliberately NO scatter, which is what
makes tracing affordable inside the event scan: XLA lowers a masked
scatter to a serialized per-lane write on CPU, ~35% sweep overhead
*per scattered array*, while compact-gather + ``concatenate`` +
``dynamic_slice`` are contiguous vectorized ops) and slides the window
left by the event count, so once ``head > capacity`` the OLDEST events
fall off the front deterministically. ``overflowed`` is derived, not
stored: ``head > capacity``. Decoding (host-side, see ``decode``) is a
plain tail slice — the window is already chronological.

All seven event fields live as f32 columns of the matrix; the integer
fields (kind, job, stage, policy, step) are exact in f32 because their
values stay far below 2**24. Column order is ``FIELDS``:

  kind   f32 col 0  event kind (EV_*; 0 = empty slot)
  t      f32 col 1  simulation time of the event
  job    f32 col 2  job-table row
  stage  f32 col 3  workflow stage index, -1 for background jobs
  cores  f32 col 4  the job's core width
  policy f32 col 5  scenario policy id (BIGJOB..RL)
  step   f32 col 6  ``ScenarioState.steps`` value when appended (1-based)
  head   i32 ()     total events ever appended (window slide + overflow)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# --- event kinds (0 is reserved for "empty slot") ---------------------------
EV_SUBMIT = 1     # job admitted into the FCFS queue (incl. resubmissions)
EV_START = 2      # job started running (scheduling pass)
EV_FINISH = 3     # running job completed
EV_CANCEL = 4     # naive/RL early allocation cancelled at its start instant
EV_RESUBMIT = 5   # cancelled successor released by predecessor completion
EV_KILL = 6       # running job killed by a node failure, requeued in place

EVENT_NAMES = {
    EV_SUBMIT: "submit",
    EV_START: "start",
    EV_FINISH: "finish",
    EV_CANCEL: "cancel",
    EV_RESUBMIT: "resubmit",
    EV_KILL: "kill",
}

FIELDS = ("kind", "t", "job", "stage", "cores", "policy", "step")
NF = len(FIELDS)
_COL = {f: i for i, f in enumerate(FIELDS)}
_INT_FIELDS = ("kind", "job", "stage", "policy", "step")


class TraceBuffer(NamedTuple):
    """One scenario's event window (a pytree; vmap the leading axis)."""

    data: jax.Array     # f32 (C, NF) newest events right-aligned
    head: jax.Array     # i32 () events ever appended


def init(capacity: int) -> TraceBuffer:
    """An empty ring of ``capacity`` event slots."""
    if capacity < 1:
        raise ValueError(f"trace capacity must be >= 1, got {capacity}")
    return TraceBuffer(data=jnp.zeros((capacity, NF), jnp.float32),
                       head=jnp.int32(0))


def capacity(tr: TraceBuffer) -> int:
    return int(tr.data.shape[-2])


def overflowed(tr: TraceBuffer) -> jax.Array:
    """True once at least one event has been dropped (window slid past)."""
    return tr.head > tr.data.shape[-2]


def column(tr: TraceBuffer, field: str) -> jax.Array:
    """One field's (C,) column (f32 — cast on the host if needed)."""
    return tr.data[..., _COL[field]]


def _rows(mask: jax.Array, kind: jax.Array, job: jax.Array,
          stage: jax.Array, cores: jax.Array, t: jax.Array,
          policy: jax.Array, step: jax.Array) -> jax.Array:
    """(L, NF) f32 event rows in FIELDS column order (lane-aligned)."""
    L = mask.shape[0]

    def b(v):
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32), (L,))

    return jnp.stack([b(kind), b(t), b(job), b(stage), b(cores),
                      b(policy), b(step)], axis=1)


def _slide(data: jax.Array, dense: jax.Array,
           cnt: jax.Array) -> jax.Array:
    """Append ``dense[:cnt]`` rows, dropping the oldest ``cnt`` rows.

    ``dense`` rows at index >= cnt are garbage and provably never enter
    the window: with ``ext = concat(data, dense)`` the slice
    ``ext[cnt : cnt + C]`` covers ``data[cnt:]`` plus ``dense[:cnt]``.
    """
    C = data.shape[0]
    ext = jnp.concatenate([data, dense], axis=0)
    return jax.lax.dynamic_slice(ext, (cnt, jnp.int32(0)), (C, NF))


def _append(tr: TraceBuffer, mask: jax.Array, kind: jax.Array,
            job: jax.Array, stage: jax.Array, cores: jax.Array,
            t: jax.Array, policy: jax.Array,
            step: jax.Array) -> TraceBuffer:
    """Masked multi-event window write (kind is per-lane here)."""
    L = mask.shape[0]
    m32 = mask.astype(jnp.int32)
    cnt = jnp.sum(m32)
    # dense lane-ordered prefix: row k = the (k+1)-th True lane. cumsum
    # is strictly increasing on True lanes, so searchsorted(cs, k+1)
    # finds exactly that lane; ranks past cnt clamp to a garbage row
    # that _slide never exposes.
    cs = jnp.cumsum(m32)
    src = jnp.searchsorted(cs, jnp.arange(1, L + 1, dtype=cs.dtype),
                           side="left")
    src = jnp.minimum(src, L - 1)
    rows = _rows(mask, kind, job, stage, cores, t, policy, step)
    dense = jnp.take(rows, src, axis=0)
    return TraceBuffer(data=_slide(tr.data, dense, cnt),
                       head=tr.head + cnt)


def append_masked(tr: TraceBuffer, mask: jax.Array, *, kind: int,
                  t: jax.Array, job: jax.Array, stage: jax.Array,
                  cores: jax.Array, policy: jax.Array,
                  step: jax.Array) -> TraceBuffer:
    """Append one event per True lane of ``mask`` (lane order).

    ``job``/``stage``/``cores`` are per-lane arrays, ``t``/``policy``/
    ``step`` scalars. ``head`` advances by the full masked count even
    when it exceeds the capacity; in that (pathological: more events in
    ONE append than the whole ring holds) case the window lands
    entirely inside the new batch and only its newest ``capacity``
    lanes survive — the drop order stays deterministic.
    """
    return _append(tr, mask, jnp.int32(kind), job, stage, cores,
                   t, policy, step)


def append_segments(tr: TraceBuffer,
                    segments, *, t: jax.Array, policy: jax.Array,
                    step: jax.Array) -> TraceBuffer:
    """Fuse several same-instant masked appends into ONE window write.

    ``segments`` is a sequence of ``(mask, kind, job, stage, cores)``
    tuples; events land in segment order (then lane order within a
    segment) — exactly the order the equivalent ``append_masked`` chain
    would produce, for one cumsum/searchsorted/slide instead of one per
    segment.
    """
    masks, kinds, jobs, stages, widths = [], [], [], [], []
    for mask, kind, job, stage, cores in segments:
        masks.append(mask)
        kinds.append(jnp.full(mask.shape, kind, jnp.int32))
        jobs.append(job)
        stages.append(stage)
        widths.append(cores)
    return _append(tr, jnp.concatenate(masks), jnp.concatenate(kinds),
                   jnp.concatenate(jobs), jnp.concatenate(stages),
                   jnp.concatenate(widths), t, policy, step)


def append_if(tr: TraceBuffer, flag: jax.Array, *, kind: int, t: jax.Array,
              job: jax.Array, stage: jax.Array, cores: jax.Array,
              policy: jax.Array, step: jax.Array) -> TraceBuffer:
    """Append a single event when the scalar ``flag`` is True."""
    row = _rows(jnp.ones((1,), bool), kind, job, stage, cores, t,
                policy, step)
    return TraceBuffer(
        data=_slide(tr.data, row, flag.astype(jnp.int32)),
        head=tr.head + flag.astype(jnp.int32),
    )


# ------------------------------------------------------- host-side decoding


def decode(tr: TraceBuffer) -> tuple[dict[str, np.ndarray], dict]:
    """Decode ONE scenario's ring into chronological order (host side).

    Returns ``(events, meta)``: ``events`` maps each field name to an
    oldest-first array of the surviving events; ``meta`` records
    ``capacity``, ``total`` (events ever appended), ``kept``,
    ``dropped`` and the ``overflowed`` flag.
    """
    data = np.asarray(tr.data)
    if data.ndim != 2:
        raise ValueError("decode takes a single scenario's TraceBuffer; "
                         "use decode_batch for a batched one")
    C = data.shape[0]
    total = int(np.asarray(tr.head))
    kept = min(total, C)
    window = data[C - kept:]  # already chronological (window invariant)
    events = {}
    for f, col in _COL.items():
        v = window[:, col]
        events[f] = (v.astype(np.int32) if f in _INT_FIELDS
                     else v.astype(np.float32))
    meta = {"capacity": C, "total": total, "kept": kept,
            "dropped": total - kept, "overflowed": total > C}
    return events, meta


def decode_batch(tr: TraceBuffer) -> list[tuple[dict[str, np.ndarray], dict]]:
    """``decode`` every scenario of a batched (B, C, NF) TraceBuffer."""
    host = TraceBuffer(*[np.asarray(x) for x in tr])
    B = host.head.shape[0]
    return [decode(TraceBuffer(*[x[i] for x in host])) for i in range(B)]
