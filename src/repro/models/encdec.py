"""Whisper-style encoder–decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_model). The backbone is
faithful: LayerNorm + GELU MLPs, learned positions, bidirectional encoder,
causal decoder with cross-attention to the encoder output.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import padded_vocab, _unembed
from repro.models.scan_util import maybe_scan


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.init_norm(cfg, with_bias=True),
        "attn": L.init_attention(ks[0], cfg),
        "mlp_norm": L.init_norm(cfg, with_bias=True),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": L.init_norm(cfg, with_bias=True),
        "attn": L.init_attention(ks[0], cfg),
        "xattn_norm": L.init_norm(cfg, with_bias=True),
        "xattn": L.init_attention(ks[1], cfg),
        "mlp_norm": L.init_norm(cfg, with_bias=True),
        "mlp": L.init_mlp(ks[2], cfg),
    }


def init_lm(key, cfg: ModelConfig) -> dict:
    enc = cfg.encoder
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], enc.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    pv = padded_vocab(cfg)
    return {
        "enc_pos": L._dense_init(ks[2], (enc.n_frames, cfg.d_model),
                                 scale=0.02),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_final_norm": L.init_norm(cfg, with_bias=True),
        "embed": L.init_embedding(ks[3], cfg, pv),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": L.init_norm(cfg, with_bias=True),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig, *,
           remat: str = "none", unroll: bool = False) -> jax.Array:
    """frames: (B, n_frames, D) stub embeddings -> encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos"][: x.shape[1]].astype(x.dtype)

    def body(lp, x):
        h, _ = L.attention(
            lp["attn"],
            L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, "layernorm"),
            cfg, causal=False)
        x = x + h
        x = x + L.apply_mlp(
            lp["mlp"],
            L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, "layernorm"),
            cfg.mlp)
        return x

    if remat != "none":
        body = jax.checkpoint(body)

    x, _ = maybe_scan(lambda x, lp: (body(lp, x), None), x,
                      params["enc_layers"], unroll=unroll)
    return L.apply_norm(params["enc_final_norm"], x, cfg.norm_eps,
                        "layernorm")


def _cross_kv(lp, enc_out, cfg):
    dtype = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"].astype(dtype))
    if "bk" in lp["xattn"]:
        k = k + lp["xattn"]["bk"].astype(dtype)
        v = v + lp["xattn"]["bv"].astype(dtype)
    return k, v


def decode_train(params, tokens, enc_out, cfg: ModelConfig, *,
                 remat: str = "none", unroll: bool = False) -> jax.Array:
    """Teacher-forced decoder pass -> logits."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], tokens, dtype)
    x = x + params["embed"]["pos"][: x.shape[1]].astype(dtype)

    def body(lp, x):
        h, _ = L.attention(
            lp["attn"],
            L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, "layernorm"),
            cfg, causal=True)
        x = x + h
        ck = _cross_kv(lp, enc_out, cfg)
        h, _ = L.attention(
            lp["xattn"],
            L.apply_norm(lp["xattn_norm"], x, cfg.norm_eps, "layernorm"),
            cfg, cross_kv=ck)
        x = x + h
        x = x + L.apply_mlp(
            lp["mlp"],
            L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, "layernorm"),
            cfg.mlp)
        return x

    if remat != "none":
        body = jax.checkpoint(body)

    x, _ = maybe_scan(lambda x, lp: (body(lp, x), None), x,
                      params["dec_layers"], unroll=unroll)
    return _unembed(params, x, cfg)


def forward(params, tokens, frames, cfg: ModelConfig, *,
            remat: str = "none", unroll: bool = False) -> jax.Array:
    enc_out = encode(params, frames, cfg, remat=remat, unroll=unroll)
    return decode_train(params, tokens, enc_out, cfg, remat=remat,
                        unroll=unroll)


def init_kv_caches(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kv = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    xk = (cfg.n_layers, batch, cfg.encoder.n_frames, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
        "xk": jnp.zeros(xk, dtype), "xv": jnp.zeros(xk, dtype),
    }


def precompute_cross_kv(params, enc_out, cfg: ModelConfig):
    """Cross-attention K/V once per request (decode-time optimization)."""
    def per_layer(lp):
        return _cross_kv(lp, enc_out, cfg)
    ks, vs = jax.vmap(per_layer, in_axes=(0,))(params["dec_layers"])
    return ks, vs


def decode_step(params, token, caches, index, cfg: ModelConfig, *,
                unroll: bool = False):
    """One decoder step with self-attn KV cache + precomputed cross-KV."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], token, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["embed"]["pos"], index, 1, axis=0).astype(dtype)[None]

    def scan_fn(x, inp):
        lp, k_l, v_l, xk_l, xv_l = inp
        h, kv = L.attention(
            lp["attn"],
            L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, "layernorm"),
            cfg, causal=True, kv_cache={"k": k_l, "v": v_l},
            cache_index=index,
            positions=index[None, None].astype(jnp.int32))
        x = x + h
        h, _ = L.attention(
            lp["xattn"],
            L.apply_norm(lp["xattn_norm"], x, cfg.norm_eps, "layernorm"),
            cfg, cross_kv=(xk_l, xv_l))
        x = x + h
        x = x + L.apply_mlp(
            lp["mlp"],
            L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, "layernorm"),
            cfg.mlp)
        return x, (kv["k"], kv["v"])

    x, (nk, nv) = maybe_scan(
        scan_fn, x,
        (params["dec_layers"], caches["k"], caches["v"],
         caches["xk"], caches["xv"]), unroll=unroll, with_ys=True)
    logits = _unembed(params, x, cfg)
    return logits, {"k": nk, "v": nv, "xk": caches["xk"], "xv": caches["xv"]}
