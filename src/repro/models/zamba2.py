"""Zamba2 (arXiv:2411.15242): Mamba2 (SSD) backbone + a SHARED
attention+MLP block invoked every ``attn_every`` layers (the same weights
each time — Zamba's parameter-sharing trick), concatenating the backbone
input with the original embedding.

Mamba2 SSD block (simplified, faithful in structure):
  in_proj -> [z (gate), x, B, C, dt]   per head: x:(P,), B,C:(N,), dt scalar
  short depthwise conv on x/B/C (width 4)
  recurrence per head:  h_t = exp(A·dt_t) h_{t-1} + dt_t · (B_t ⊗ x_t)
                        y_t = C_t · h_t + D ⊙ x_t
  gate: y ⊙ silu(z), out_proj.

Chunked evaluation mirrors rwkv6 (scalar per-head decay makes it simpler);
decode is the O(1) single-step recurrence — zamba2 runs long_500k with its
shared attention restricted to a sliding window.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.scan_util import maybe_scan
from repro.models.layers import (
    _dense_init, apply_norm, init_norm, init_attention, init_mlp,
    apply_mlp, attention,
)


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    H = d_inner // cfg.ssm.head_dim
    return d_inner, H, cfg.ssm.head_dim, cfg.ssm.state_dim


def init_mamba2(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in_z": _dense_init(ks[0], (D, d_inner)),
        "w_in_x": _dense_init(ks[1], (D, d_inner)),
        "w_in_B": _dense_init(ks[2], (D, H, N)),
        "w_in_C": _dense_init(ks[3], (D, H, N)),
        "w_in_dt": _dense_init(ks[4], (D, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D_skip": jnp.ones((H, P), jnp.float32),
        "conv_x": _dense_init(ks[5], (cfg.ssm.conv_width, d_inner), scale=0.5),
        "out_norm": jnp.ones((d_inner,), jnp.float32),
        "w_out": _dense_init(ks[5], (d_inner, D)),
    }


def _short_conv(x, w, carry: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C). carry: (B,W-1,C)."""
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(W))
    return jax.nn.silu(out), xp[:, -(W - 1):]


def ssd_chunked(xh, Bh, Ch, dt, A, chunk: int, state0=None,
                unroll: bool = False):
    """Chunked SSD scan.
    xh: (B,S,H,P); Bh,Ch: (B,S,H,N); dt: (B,S,H); A: (H,) (positive decay rate).
    h state: (B,H,N,P).  Returns (y (B,S,H,P), final state)."""
    Bsz, S, H, P = xh.shape
    N = Bh.shape[-1]
    n = S // chunk
    xf = xh.astype(jnp.float32).reshape(Bsz, n, chunk, H, P)
    Bf = Bh.astype(jnp.float32).reshape(Bsz, n, chunk, H, N)
    Cf = Ch.astype(jnp.float32).reshape(Bsz, n, chunk, H, N)
    dtf = dt.astype(jnp.float32).reshape(Bsz, n, chunk, H)
    logw = -A[None, None, None, :] * dtf          # (B,n,c,H) per-step log decay
    cum = jnp.cumsum(logw, axis=2)
    state0 = (jnp.zeros((Bsz, H, N, P), jnp.float32)
              if state0 is None else state0.astype(jnp.float32))

    def scan_chunk(state, inp):
        xc, Bc, Cc, dtc, cumc, logwc = inp
        # inter-chunk: h_t sees the carried state decayed by Π_{u≤t} w_u
        C_dec = Cc * jnp.exp(cumc)[..., None]
        y_inter = jnp.einsum("bthn,bhnp->bthp", C_dec, state)
        # intra-chunk: PAIRWISE decay exp(cum_t − cum_s) for s ≤ t.
        # The exponent is ≤ 0 inside the mask, so this form never overflows
        # (the factored exp(cum_t)·exp(−cum_s) form does).
        dec = cumc[:, :, None, :] - cumc[:, None, :, :]  # (B,t,s,H)
        c = xc.shape[1]
        mask = jnp.tril(jnp.ones((c, c), bool))   # inclusive: s ≤ t
        dec = jnp.where(mask[None, :, :, None], dec, -jnp.inf)
        att = jnp.einsum("bthn,bshn->bhts", Cc, Bc) * jnp.exp(
            jnp.moveaxis(dec, 3, 1))
        xdt = xc * dtc[..., None]
        y_intra = jnp.einsum("bhts,bshp->bthp", att, xdt)
        cum_end = cumc[:, -1:, :]
        B_dec = Bc * jnp.exp(cum_end - cumc)[..., None]  # exponent ≤ 0
        state = (jnp.exp(cum_end[:, 0])[..., None, None] * state
                 + jnp.einsum("bshn,bshp->bhnp", B_dec, xdt))
        return state, y_inter + y_intra

    inputs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (xf, Bf, Cf, dtf, cum, logw))
    state, ys = maybe_scan(scan_chunk, state0, inputs, unroll=unroll,
                           with_ys=True)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), state


def ssd_step(xh, Bh, Ch, dt, A, state):
    """Single decode step. xh:(B,H,P), Bh/Ch:(B,H,N), dt:(B,H)."""
    xf, Bf, Cf = (a.astype(jnp.float32) for a in (xh, Bh, Ch))
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(-A[None] * dtf)                        # (B,H)
    upd = jnp.einsum("bhn,bhp->bhnp", Bf, xf * dtf[..., None])
    state = decay[..., None, None] * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cf, state)
    return y.astype(xh.dtype), state


def mamba2_block(p, x, cfg: ModelConfig, *, conv_carry=None, ssm_state=None,
                 unroll: bool = False):
    """x: (B,S,D) -> (y, (conv_carry, ssm_state))."""
    B, S, D = x.shape
    d_inner, H, P, N = dims(cfg)
    dtype = x.dtype
    z = x @ p["w_in_z"].astype(dtype)
    xi = x @ p["w_in_x"].astype(dtype)
    xi, new_conv = _short_conv(xi, p["conv_x"], conv_carry)
    Bh = jnp.einsum("bsd,dhn->bshn", x, p["w_in_B"].astype(dtype))
    Ch = jnp.einsum("bsd,dhn->bshn", x, p["w_in_C"].astype(dtype))
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["w_in_dt"].astype(dtype))
                         .astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])
    xh = xi.reshape(B, S, H, P)
    if S == 1 and ssm_state is not None:
        y, state = ssd_step(xh[:, 0], Bh[:, 0], Ch[:, 0], dt[:, 0], A, ssm_state)
        y = y[:, None]
    else:
        y, state = ssd_chunked(xh, Bh, Ch, dt, A,
                               chunk=min(cfg.ssm.chunk, S), state0=ssm_state,
                               unroll=unroll)
    y = y + xh * p["D_skip"].astype(dtype)[None, None]
    y = y.reshape(B, S, d_inner)
    # RMS out-norm then gate
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)
    y = (yf * p["out_norm"]).astype(dtype) * jax.nn.silu(z)
    return y @ p["w_out"].astype(dtype), (new_conv, state)


# ------------------------------------------------------------------ model


def init_lm(key, cfg: ModelConfig) -> dict:
    from repro.models.transformer import padded_vocab
    from repro.models import layers as Lay
    ks = jax.random.split(key, 6)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)

    def layer_init(k):
        return {"norm": init_norm(cfg), "mamba": init_mamba2(k, cfg)}

    stacked = jax.vmap(layer_init)(layer_keys)
    pv = padded_vocab(cfg)
    return {
        "embed": Lay.init_embedding(ks[1], cfg, pv),
        "layers": stacked,
        # the SHARED attention+MLP block (one set of weights, reused)
        "shared_norm": init_norm(cfg),
        "shared_attn": init_attention(ks[2], cfg),
        "shared_mlp_norm": init_norm(cfg),
        "shared_mlp": init_mlp(ks[3], cfg),
        "final_norm": init_norm(cfg),
        "lm_head": _dense_init(ks[4], (cfg.d_model, pv), scale=0.02),
    }


def _shared_block(params, x, cfg, *, kv_cache=None, cache_index=None):
    h, kv = attention(
        params["shared_attn"],
        apply_norm(params["shared_norm"], x, cfg.norm_eps),
        cfg, causal=True,
        positions=(None if cache_index is None
                   else cache_index[None, None].astype(jnp.int32)),
        kv_cache=kv_cache, cache_index=cache_index)
    x = x + h
    x = x + apply_mlp(params["shared_mlp"],
                      apply_norm(params["shared_mlp_norm"], x, cfg.norm_eps),
                      cfg.mlp)
    return x, kv


def forward(params, tokens, cfg: ModelConfig, *, remat: str = "none",
            unroll: bool = False):
    from repro.models.transformer import _unembed
    from repro.models.layers import embed
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dtype)
    period = max(cfg.attn_every, 1)

    def body(lp, x, i):
        h, _ = mamba2_block(lp["mamba"],
                            apply_norm(lp["norm"], x, cfg.norm_eps), cfg,
                            unroll=unroll)
        x = x + h
        # shared attention every `period` layers (same weights each time)
        use_attn = (i % period) == (period - 1) if cfg.attn_every else False
        if cfg.attn_every:
            x = jax.lax.cond(
                use_attn,
                lambda x: _shared_block(params, x, cfg)[0],
                lambda x: x,
                x)
        return x

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_fn(carry, lp_i):
        x, i = carry
        lp = lp_i
        x = body(lp, x, i)
        return (x, i + 1), None

    (x, _), _ = maybe_scan(scan_fn, (x, jnp.int32(0)), params["layers"],
                           unroll=unroll)
    return _unembed(params, x, cfg)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    d_inner, H, P, N = dims(cfg)
    n_attn = cfg.n_layers // max(cfg.attn_every, 1) if cfg.attn_every else 0
    window = cfg.sliding_window or max_seq
    cache_len = min(window, max_seq)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm.conv_width - 1,
                           d_inner), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, H, N, P), jnp.float32),
        "attn_k": jnp.zeros((max(n_attn, 1), batch, cache_len,
                             cfg.n_kv_heads, cfg.hd), dtype),
        "attn_v": jnp.zeros((max(n_attn, 1), batch, cache_len,
                             cfg.n_kv_heads, cfg.hd), dtype),
    }


def decode_step(params, token, state, index, cfg: ModelConfig):
    """One decode step; sliding-window KV for the shared attention blocks."""
    from repro.models.transformer import _unembed
    from repro.models.layers import embed
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token, dtype)
    period = max(cfg.attn_every, 1)
    cache_len = state["attn_k"].shape[2]
    widx = jnp.mod(index, cache_len)  # ring-buffer write position

    new_conv, new_ssm = [], []
    new_k = state["attn_k"]
    new_v = state["attn_v"]
    a_i = 0
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        h, (cc, ss) = mamba2_block(
            lp["mamba"], apply_norm(lp["norm"], x, cfg.norm_eps), cfg,
            conv_carry=state["conv"][i], ssm_state=state["ssm"][i])
        x = x + h
        new_conv.append(cc)
        new_ssm.append(ss)
        if cfg.attn_every and (i % period) == (period - 1):
            kv = {"k": new_k[a_i], "v": new_v[a_i]}
            x, kv2 = _shared_block(params, x, cfg, kv_cache=kv,
                                   cache_index=widx)
            new_k = new_k.at[a_i].set(kv2["k"])
            new_v = new_v.at[a_i].set(kv2["v"])
            a_i += 1
    logits = _unembed(params, x, cfg)
    return logits, {
        "conv": jnp.stack(new_conv),
        "ssm": jnp.stack(new_ssm),
        "attn_k": new_k,
        "attn_v": new_v,
    }
