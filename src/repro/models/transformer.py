"""Decoder-only transformer LM (dense + MoE + VLM prefix variants).

Layers are STACKED (leading L dim) and driven by ``lax.scan`` so the HLO is
O(1) in depth — the production-correct choice for 90+-layer configs and the
only tractable one for 512-device dry-run compiles on this container.
Activation checkpointing wraps the scanned body (``remat_policy``).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.scan_util import maybe_scan

VOCAB_PAD_MULTIPLE = 256


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    return -(-v // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


def _init_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(ks[0], cfg),
        "mlp_norm": L.init_norm(cfg),
    }
    if cfg.moe:
        p["moe"] = MOE.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def init_lm(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    pv = padded_vocab(cfg)
    params = {
        "embed": L.init_embedding(ks[1], cfg, pv),
        "layers": stacked,
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(ks[2], (cfg.d_model, pv), scale=0.02)
    return params


def _layer_apply(lp: dict, x: jax.Array, cfg: ModelConfig, *,
                 positions, use_flash: bool, use_moe_kernel: bool = False):
    h, _ = L.attention(
        lp["attn"], L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, cfg.norm),
        cfg, causal=True, positions=positions, use_flash=use_flash)
    x = x + h
    hn = L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, cfg.norm)
    if cfg.moe:
        x = x + MOE.apply_moe(lp["moe"], hn, cfg, use_kernel=use_moe_kernel)
    else:
        x = x + L.apply_mlp(lp["mlp"], hn, cfg.mlp)
    return x


def _unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    # mask vocab padding so the softmax ignores it
    pv, v = logits.shape[-1], cfg.vocab_size
    if pv != v:
        neg = jnp.full((pv - v,), -1e30, logits.dtype)
        logits = logits.at[..., v:].set(neg)
    return logits


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            prefix_embeds: Optional[jax.Array] = None,
            use_flash: bool = False,
            remat: str = "none", unroll: bool = False,
            return_hidden: bool = False) -> jax.Array:
    """Training/eval forward -> logits (B, S[, +P], V_padded).

    prefix_embeds: (B, P, D) precomputed modality embeddings (VLM stub) that
    are prepended to the token embeddings (loss masking is the caller's job).
    remat: none | full | dots — activation checkpoint policy on the layer.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], tokens, dtype)
    if getattr(cfg, "pos", "rope") == "learned":
        S = x.shape[1]
        x = x + params["embed"]["pos"][:S].astype(dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]

    body = partial(_layer_apply, cfg=cfg, positions=positions,
                   use_flash=use_flash)
    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_fn(x, lp):
        return body(lp, x), None

    x, _ = maybe_scan(scan_fn, x, params["layers"], unroll=unroll)
    if return_hidden:
        return L.apply_norm(params["final_norm"], x, cfg.norm_eps, cfg.norm)
    return _unembed(params, x, cfg)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            use_flash: bool = False, unroll: bool = False):
    """Prefill pass -> (last-position logits, stacked KV caches).

    caches: {"k","v"}: (L, B, S, KV, hd) — ready for decode_step writes at
    index S.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], tokens, dtype)
    if cfg.pos == "learned":
        x = x + params["embed"]["pos"][: x.shape[1]].astype(dtype)
    positions = jnp.arange(x.shape[1])[None, :]

    def scan_fn(x, lp):
        h, kv = L.attention(
            lp["attn"],
            L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, cfg.norm),
            cfg, causal=True, positions=positions, use_flash=use_flash)
        x = x + h
        hn = L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, cfg.norm)
        if cfg.moe:
            x = x + MOE.apply_moe(lp["moe"], hn, cfg)
        else:
            x = x + L.apply_mlp(lp["mlp"], hn, cfg.mlp)
        return x, kv

    x, caches = maybe_scan(scan_fn, x, params["layers"], unroll=unroll,
                           with_ys=True)
    logits = _unembed(params, x[:, -1:, :], cfg)
    return logits, caches


def decode_step(params: dict, token: jax.Array, caches: dict,
                index: jax.Array, cfg: ModelConfig, *,
                unroll: bool = False):
    """One decode step. token: (B, 1) int32; caches: (L,B,S,KV,hd);
    index: scalar int32 write position. -> (logits (B,1,V), new caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], token, dtype)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["embed"]["pos"], index, 1, axis=0).astype(dtype)[None]
    positions = index[None, None].astype(jnp.int32)

    def scan_fn(x, layer_and_cache):
        lp, cache_l = layer_and_cache
        h, new_kv = L.attention(
            lp["attn"],
            L.apply_norm(lp["attn_norm"], x, cfg.norm_eps, cfg.norm),
            cfg, causal=True, positions=positions,
            kv_cache=cache_l, cache_index=index)
        x = x + h
        hn = L.apply_norm(lp["mlp_norm"], x, cfg.norm_eps, cfg.norm)
        if cfg.moe:
            x = x + MOE.apply_moe(lp["moe"], hn, cfg)
        else:
            x = x + L.apply_mlp(lp["mlp"], hn, cfg.mlp)
        return x, new_kv

    x, new_caches = maybe_scan(scan_fn, x, (params["layers"], caches),
                               unroll=unroll, with_ys=True)
    logits = _unembed(params, x, cfg)
    return logits, new_caches


def init_kv_caches(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def loss_fn(params: dict, tokens: jax.Array, labels: jax.Array,
            cfg: ModelConfig, *, prefix_embeds=None, use_flash=False,
            remat: str = "dots", unroll: bool = False) -> jax.Array:
    logits = forward(params, tokens, cfg, prefix_embeds=prefix_embeds,
                     use_flash=use_flash, remat=remat, unroll=unroll)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def unembed_matrix(params: dict, cfg: ModelConfig, dtype):
    if cfg.tie_embeddings:
        return params["embed"]["table"].astype(dtype).T
    return params["lm_head"].astype(dtype)


def vocab_parallel_xent(hidden: jax.Array, params: dict, labels: jax.Array,
                        cfg: ModelConfig) -> jax.Array:
    """Cross-entropy WITHOUT materializing/gathering full logits.

    The unembed matrix stays vocab-sharded (model axis); the reductions
    (max, sum-exp, label pick) are over the sharded vocab axis, so SPMD
    lowers them to (B,S)-sized all-reduces instead of the (B,S,V) logits
    all-gather of the naive path. The label logit is picked with a one-hot
    einsum (gather over a sharded axis would force a full gather); vocab
    padding is masked additively via iota (no .at[].set layout change).
    """
    w = unembed_matrix(params, cfg, hidden.dtype)       # (D, Vp)
    logits = (hidden @ w).astype(jnp.float32)           # (B,S,Vp) v-sharded
    pv, v = logits.shape[-1], cfg.vocab_size
    if pv != v:
        pad_mask = (jnp.arange(pv) >= v).astype(jnp.float32) * -1e30
        logits = logits + pad_mask
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, pv, dtype=jnp.float32)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(lse - label_logit)
