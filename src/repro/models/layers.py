"""Common layers: norms, RoPE, GQA attention (train/prefill/decode), MLPs.

Pure-functional style: ``init_*(key, cfg) -> params`` and
``apply(params, x, ...) -> y``. Parameters are nested dicts of jnp arrays so
the sharding rule engine (repro.parallel.sharding) can pattern-match paths.

Attention uses the Pallas flash kernel on the prefill/train path when
enabled (repro.kernels.flash_attention.ops); falls back to the jnp reference
everywhere else (decode, CPU smoke).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ------------------------------------------------------------------ inits


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


def init_norm(cfg: ModelConfig, with_bias: Optional[bool] = None) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if with_bias if with_bias is not None else cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-5,
               kind: str = "rmsnorm") -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


# ------------------------------------------------------------------- RoPE


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                             # (..., S, 1, hd/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention


def init_attention(key, cfg: ModelConfig) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd)),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
    return p


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def sdpa(q, k, v, *, causal: bool, window: int = 0,
         q_offset: jax.Array | int = 0) -> jax.Array:
    """Reference scaled-dot-product attention.
    q: (B,Sq,H,hd), k/v: (B,Sk,H,hd). q_offset: absolute pos of q[0]."""
    hd = q.shape[-1]
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k) / math.sqrt(hd)
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", w, v)


def chunked_sdpa(q, k, v, *, causal: bool, window: int = 0,
                 chunk: int = 1024) -> jax.Array:
    """Flash-equivalent attention in pure jnp: iterate kv blocks with a
    running (max, denom, acc) — O(Sq·chunk) live memory instead of O(Sq·Sk).
    This is the CPU-loweriable twin of kernels/flash_attention (same math,
    same memory behaviour), used for dry-run/roofline lowers and as the
    non-TPU production path. Python loop (not scan) so HloCostAnalysis sees
    every block."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32)
    m = jnp.full((B, H, Sq), -1e30, jnp.float32)
    denom = jnp.zeros((B, H, Sq), jnp.float32)
    acc = jnp.zeros((B, Sq, H, hd), jnp.float32)
    qpos = jnp.arange(Sq)
    for ci in range(Sk // chunk):
        k0 = ci * chunk
        if causal and k0 > Sq - 1:
            break
        kc = k[:, k0:k0 + chunk].astype(jnp.float32)
        vc = v[:, k0:k0 + chunk].astype(jnp.float32)
        s = jnp.einsum("bqhk,bshk->bhqs", qf, kc) * scale
        kpos = k0 + jnp.arange(chunk)
        mask = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        denom = denom * alpha + jnp.sum(p_, axis=-1)
        acc = (acc * alpha.transpose(0, 2, 1)[..., None]
               + jnp.einsum("bhqs,bshk->bqhk", p_, vc))
        m = m_new
    out = acc / jnp.maximum(denom, 1e-20).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attention(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    kv_cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    cross_kv: Optional[tuple] = None,
    use_flash: bool = False,
):
    """GQA attention for train/prefill (kv_cache None) or decode.

    decode: x is (B,1,D); kv_cache = {"k": (B,S,KV,hd), "v": ...} is updated
    at ``cache_index`` and attention runs over the full cache with a length
    mask. Returns (out, new_kv_cache).
    """
    B, S, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
        k, v = cross_kv
        if cfg.pos == "rope":
            pass  # no rope on cross attention
        out = sdpa(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep), causal=False)
        out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
        return out, None

    q, k, v = _qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        kf = repeat_kv(k, n_rep)
        vf = repeat_kv(v, n_rep)
        if use_flash:
            if jax.default_backend() == "tpu":
                from repro.kernels.flash_attention import ops as flash_ops
                out = flash_ops.flash_attention(
                    q, kf, vf, causal=causal, window=cfg.sliding_window)
            else:
                out = chunked_sdpa(q, kf, vf, causal=causal,
                                   window=cfg.sliding_window)
        else:
            out = sdpa(q, kf, vf, causal=causal, window=cfg.sliding_window)
        out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
        return out, {"k": k, "v": v}

    # ---- decode: update cache in place, attend over cache
    idx = cache_index  # scalar int32: current write position
    ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, idx, axis=1)
    Sk = ck.shape[1]
    kf = repeat_kv(ck, n_rep)
    vf = repeat_kv(cv, n_rep)
    hd = q.shape[-1]
    logits = jnp.einsum("bqhk,bshk->bhqs", q, kf) / math.sqrt(hd)
    kpos = jnp.arange(Sk)
    valid = kpos[None, :] <= idx  # positions written so far (incl. current)
    if cfg.sliding_window:
        valid &= kpos[None, :] > idx - cfg.sliding_window
    logits = jnp.where(valid[None, None], logits.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", w, vf)
    out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv}


# ------------------------------------------------------------------- MLPs


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], (cfg.d_model, d_ff)),
            "w_up": _dense_init(ks[1], (cfg.d_model, d_ff)),
            "w_down": _dense_init(ks[2], (d_ff, cfg.d_model)),
        }
    return {
        "w_up": _dense_init(ks[0], (cfg.d_model, d_ff)),
        "b_up": jnp.zeros((d_ff,), jnp.float32),
        "w_down": _dense_init(ks[1], (d_ff, cfg.d_model)),
        "b_down": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        g = act(x @ p["w_gate"].astype(x.dtype))
        u = x @ p["w_up"].astype(x.dtype)
        return (g * u) @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype)
                    + p["b_up"].astype(x.dtype), approximate=True)
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


# ------------------------------------------------------------- embeddings


def init_embedding(key, cfg: ModelConfig, padded_vocab: int) -> dict:
    p = {"table": _dense_init(key, (padded_vocab, cfg.d_model), scale=0.02)}
    if cfg.pos == "learned":
        p["pos"] = _dense_init(key, (cfg.max_seq, cfg.d_model), scale=0.02)
    return p


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]
