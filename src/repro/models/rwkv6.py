"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with token-shift
and data-dependent per-channel decay.

Time-mix recurrence per head (head_dim K = V dim):

    S_t = diag(w_t) · S_{t-1} + k_t^T v_t          (S: K×V state)
    o_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(decay_t)) data-dependent (LoRA on the shifted input) and
u the "bonus" for the current token. Training uses a CHUNKED evaluation
(intra-chunk dense + inter-chunk state scan) — the same scheme the Pallas
kernel (repro.kernels.rwkv6_scan) implements with VMEM tiles; decode is the
single-step recurrence, O(1) in sequence length (this is why rwkv6 runs the
long_500k cell).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, apply_norm, init_norm
from repro.models.scan_util import maybe_scan


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv.head_dim


def init_time_mix(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    r = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 10)
    H = n_heads(cfg)
    K = cfg.rwkv.head_dim
    return {
        # token-shift interpolation factors (per channel, per projection)
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_v": jnp.full((D,), 0.5, jnp.float32),
        "mu_w": jnp.full((D,), 0.5, jnp.float32),
        "mu_g": jnp.full((D,), 0.5, jnp.float32),
        "wr": _dense_init(ks[0], (D, D)),
        "wk": _dense_init(ks[1], (D, D)),
        "wv": _dense_init(ks[2], (D, D)),
        "wg": _dense_init(ks[3], (D, D)),
        "wo": _dense_init(ks[4], (D, D)),
        # data-dependent decay: LoRA  w = base + tanh(x A) B
        "decay_base": jnp.zeros((D,), jnp.float32) - 6.0,
        "decay_A": _dense_init(ks[5], (D, r)),
        "decay_B": _dense_init(ks[6], (r, D), scale=0.01),
        "bonus_u": jnp.zeros((H, K), jnp.float32),
        "ln_x": jnp.ones((D,), jnp.float32),  # group-norm scale on output
    }


def init_channel_mix(key, cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "w_in": _dense_init(ks[0], (D, F)),
        "w_out": _dense_init(ks[1], (F, D)),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x: (B,S,D) -> x shifted right one step; prev: (B,1,D) carry for decode."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _project(p, x, xs, dtype):
    r = _mix(x, xs, p["mu_r"].astype(dtype)) @ p["wr"].astype(dtype)
    k = _mix(x, xs, p["mu_k"].astype(dtype)) @ p["wk"].astype(dtype)
    v = _mix(x, xs, p["mu_v"].astype(dtype)) @ p["wv"].astype(dtype)
    g = _mix(x, xs, p["mu_g"].astype(dtype)) @ p["wg"].astype(dtype)
    xw = _mix(x, xs, p["mu_w"].astype(dtype))
    decay = (p["decay_base"].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
             @ p["decay_B"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay))  # (B,S,D) in (0,1)
    return r, k, v, g, w


def wkv_chunked(r, k, v, w, u, chunk: int, state0=None, use_kernel: bool = False,
                unroll: bool = False):
    """Chunked WKV evaluation.

    r,k,v,w: (B, S, H, K) (V dim == K); u: (H, K).
    Returns (out (B,S,H,K), final state (B,H,K,K)).

    Math (per head; state S is K_dim × V_dim):
      o_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    Chunking: within a chunk of length c, cumulative decays give
      o = intra-chunk (masked, decay-weighted) + r·(cumdecay · S_carry)
    """
    if use_kernel:
        from repro.kernels.rwkv6_scan import ops as wkv_ops
        return wkv_ops.wkv6(r, k, v, w, u, chunk=chunk, state0=state0)

    B, S, H, K = r.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    rc = r.reshape(B, n, chunk, H, K)
    kc = k.reshape(B, n, chunk, H, K)
    vc = v.reshape(B, n, chunk, H, K)
    wc = w.reshape(B, n, chunk, H, K).astype(jnp.float32)

    logw = jnp.log(jnp.clip(wc, 1e-12, 1.0))
    cum = jnp.cumsum(logw, axis=2)            # inclusive cumulative log-decay
    state0 = (jnp.zeros((B, H, K, K), jnp.float32)
              if state0 is None else state0.astype(jnp.float32))

    def scan_chunk(state, inp):
        rc_, kc_, vc_, cum_, logw_ = inp       # (B,c,H,K) each
        rf = rc_.astype(jnp.float32)
        kf = kc_.astype(jnp.float32)
        vf = vc_.astype(jnp.float32)
        # decay from chunk start to t-1 (exclusive cumulation)
        cum_excl = cum_ - logw_
        # inter-chunk: o_inter[t] = (r_t ⊙ exp(cum_excl_t)) @ state
        r_dec = rf * jnp.exp(cum_excl)
        o_inter = jnp.einsum("bthk,bhkv->bthv", r_dec, state)
        # intra-chunk, pair (t, s<t): per-channel decay
        # exp(cum_excl_t − cum_s), exponent ≤ 0 inside the strict mask —
        # the PAIRWISE form is overflow-safe (the factored
        # exp(cum_excl)·exp(−cum) form is not).
        c = rf.shape[1]
        dec = cum_excl[:, :, None] - cum_[:, None, :, :]     # (B,t,s,H,K)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        dec = jnp.where(mask[None, :, :, None, None], dec, -jnp.inf)
        att = jnp.einsum("bthk,bshk,btshk->bhts", rf, kf, jnp.exp(dec))
        o_intra = jnp.einsum("bhts,bshv->bthv", att, vf)
        # bonus (current token): r_t · (u ⊙ k_t ⊗ v_t)
        o_bonus = jnp.einsum("bthk,hk,bthk->bth", rf, u.astype(jnp.float32),
                             kf)[..., None] * vf
        # state update: S' = exp(cum_end) S + Σ_s exp(cum_end − cum_s) k_s ⊗ v_s
        cum_end = cum_[:, -1:, :, :]
        k_dec = kf * jnp.exp(cum_end - cum_)
        state = (jnp.exp(cum_end[:, 0])[..., None] * state
                 + jnp.einsum("bshk,bshv->bhkv", k_dec, vf))
        return state, (o_inter + o_intra + o_bonus)

    inputs = (
        jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0), jnp.moveaxis(cum, 1, 0),
        jnp.moveaxis(logw, 1, 0),
    )
    state, outs = maybe_scan(scan_chunk, state0, inputs, unroll=unroll,
                             with_ys=True)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, K)
    return out.astype(r.dtype), state


def wkv_step(r, k, v, w, u, state):
    """Single decode step. r,k,v,w: (B,H,K); state: (B,H,K,K) -> (out, state')."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = w.astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    new_state = wf[..., None] * state + kv
    return out.astype(r.dtype), new_state


def time_mix(p, x, cfg: ModelConfig, *, shift_prev=None, state0=None,
             use_kernel: bool = False, unroll: bool = False):
    """Full RWKV6 time-mix block. x: (B,S,D). Returns (y, (shift_carry, state))."""
    B, S, D = x.shape
    H, K = n_heads(cfg), cfg.rwkv.head_dim
    xs = _token_shift(x, shift_prev)
    r, k, v, g, w = _project(p, x, xs, x.dtype)
    rh = r.reshape(B, S, H, K)
    kh = k.reshape(B, S, H, K)
    vh = v.reshape(B, S, H, K)
    wh = w.reshape(B, S, H, K)
    if S == 1 and state0 is not None:
        o, state = wkv_step(rh[:, 0], kh[:, 0], vh[:, 0], wh[:, 0],
                            p["bonus_u"], state0)
        o = o[:, None]
    else:
        o, state = wkv_chunked(rh, kh, vh, wh, p["bonus_u"],
                               chunk=min(cfg.rwkv.chunk, S), state0=state0,
                               use_kernel=use_kernel, unroll=unroll)
    o = o.reshape(B, S, D)
    # per-head group norm (ln_x)
    o32 = o.astype(jnp.float32).reshape(B, S, H, K)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, -1, keepdims=True) + 1e-5)
    o = (o32.reshape(B, S, D) * p["ln_x"]).astype(x.dtype)
    y = (o * jax.nn.silu(g)) @ p["wo"].astype(x.dtype)
    return y, (x[:, -1:], state)


def channel_mix(p, x, *, shift_prev=None):
    xs = _token_shift(x, shift_prev)
    xk = _mix(x, xs, p["mu_k"].astype(x.dtype))
    h = jnp.square(jax.nn.relu(xk @ p["w_in"].astype(x.dtype)))
    return h @ p["w_out"].astype(x.dtype), x[:, -1:]


# ----------------------------------------------------------------- full LM


def _init_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "tm_norm": init_norm(cfg),
        "time_mix": init_time_mix(ks[0], cfg),
        "cm_norm": init_norm(cfg),
        "channel_mix": init_channel_mix(ks[1], cfg),
    }


def init_lm(key, cfg: ModelConfig) -> dict:
    from repro.models.transformer import padded_vocab
    from repro.models import layers as Lay
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    pv = padded_vocab(cfg)
    return {
        "embed": Lay.init_embedding(ks[1], cfg, pv),
        "layers": stacked,
        "final_norm": init_norm(cfg),
        "lm_head": _dense_init(ks[2], (cfg.d_model, pv), scale=0.02),
    }


def forward(params, tokens, cfg: ModelConfig, *, remat: str = "none",
            use_kernel: bool = False, unroll: bool = False):
    from repro.models.transformer import _unembed
    dtype = jnp.dtype(cfg.dtype)
    from repro.models.layers import embed
    x = embed(params["embed"], tokens, dtype)

    def body(lp, x):
        h, _ = time_mix(lp["time_mix"],
                        apply_norm(lp["tm_norm"], x, cfg.norm_eps),
                        cfg, use_kernel=use_kernel, unroll=unroll)
        x = x + h
        h, _ = channel_mix(lp["channel_mix"],
                           apply_norm(lp["cm_norm"], x, cfg.norm_eps))
        return x + h

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_fn(x, lp):
        return body(lp, x), None

    x, _ = maybe_scan(scan_fn, x, params["layers"], unroll=unroll)
    return _unembed(params, x, cfg)


def init_decode_state(cfg: ModelConfig, batch: int) -> dict:
    H, K = n_heads(cfg), cfg.rwkv.head_dim
    return {
        "tm_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.dtype(cfg.dtype)),
        "cm_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                              jnp.dtype(cfg.dtype)),
        "wkv": jnp.zeros((cfg.n_layers, batch, H, K, K), jnp.float32),
    }


def decode_step(params, token, state, cfg: ModelConfig, *,
                unroll: bool = False):
    """O(1)-in-sequence decode. token: (B,1). -> (logits, new state)."""
    from repro.models.transformer import _unembed
    from repro.models.layers import embed
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token, dtype)

    def scan_fn(x, inp):
        lp, tm_s, cm_s, wkv_s = inp
        h, (tm_new, wkv_new) = time_mix(
            lp["time_mix"], apply_norm(lp["tm_norm"], x, cfg.norm_eps),
            cfg, shift_prev=tm_s, state0=wkv_s)
        x = x + h
        h, cm_new = channel_mix(
            lp["channel_mix"], apply_norm(lp["cm_norm"], x, cfg.norm_eps),
            shift_prev=cm_s)
        return x + h, (tm_new, cm_new, wkv_new)

    x, (tm, cm, wkv) = maybe_scan(
        scan_fn, x,
        (params["layers"], state["tm_shift"], state["cm_shift"], state["wkv"]),
        unroll=unroll, with_ys=True)
    logits = _unembed(params, x, cfg)
    return logits, {"tm_shift": tm, "cm_shift": cm, "wkv": wkv}
