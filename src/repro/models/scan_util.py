"""Scan helper: lax.scan for production HLO-size-O(1) lowering, or a fully
unrolled python loop for cost-analysis lowers (XLA's HloCostAnalysis counts
a while body exactly once, so roofline FLOP/byte numbers come from small
UNROLLED variants — see benchmarks/roofline.py)."""

from __future__ import annotations

import jax


def maybe_scan(f, carry, xs, *, unroll: bool = False, with_ys: bool = False):
    """scan f over leading axis of xs. f: (carry, x) -> (carry, y)."""
    if not unroll:
        return jax.lax.scan(f, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, x)
        ys.append(y)
    if with_ys or (ys and ys[0] is not None):
        try:
            stacked = jax.tree.map(lambda *a: jax.numpy.stack(a), *ys)
        except Exception:
            stacked = None
    else:
        stacked = None
    return carry, stacked
