"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

The dispatch is the production (MegaBlocks/MaxText-style) *sort* formulation
rather than the GShard one-hot-einsum one: the (B·S·k, E, C) dispatch tensor
of the einsum form is memory-infeasible at 32k-sequence shapes, while the
sort form is O(N·k·D) and lowers to all-to-all-friendly gathers under SPMD
when the expert dimension is sharded (EP on the "model" mesh axis).

The grouped expert GEMM ('ecd,edf->ecf') is the compute hot spot; it is
backed by the Pallas kernel in repro.kernels.moe_gmm (interpret-validated
against the jnp path used here).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (cfg.d_model, m.n_experts), scale=0.02),
        "w_gate": _dense_init(ks[1], (m.n_experts, cfg.d_model, m.d_ff_expert)),
        "w_up": _dense_init(ks[2], (m.n_experts, cfg.d_model, m.d_ff_expert)),
        "w_down": _dense_init(ks[3], (m.n_experts, m.d_ff_expert, cfg.d_model)),
    }


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              use_kernel: bool = False) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    xt = x.reshape(N, D)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)             # (N,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- sort-based dispatch with capacity dropping
    C = capacity(N, cfg)
    flat_e = expert_idx.reshape(-1)                 # (N*k,)
    flat_g = gate_vals.reshape(-1).astype(x.dtype)  # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(N), m.top_k)   # token id per slot
    order = jnp.argsort(flat_e)                     # stable
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    # position within expert group = rank - first-rank-of-that-expert
    counts = jnp.bincount(se, length=m.n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(N * m.top_k) - starts[se]
    keep = pos_in_e < C
    dest = jnp.where(keep, se * C + pos_in_e, m.n_experts * C)  # drop slot

    buf = jnp.zeros((m.n_experts * C + 1, D), x.dtype)
    buf = buf.at[dest].set(xt[stok] * keep[:, None].astype(x.dtype))
    eb = buf[:-1].reshape(m.n_experts, C, D)

    # ---- grouped expert FFN (hot spot)
    if use_kernel:
        from repro.kernels.moe_gmm import ops as gmm_ops
        h = gmm_ops.grouped_ffn(eb, p["w_gate"], p["w_up"], p["w_down"],
                                mlp=cfg.mlp)
    else:
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb,
                                   p["w_gate"].astype(x.dtype)))
        u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"].astype(x.dtype))
        h = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))

    # ---- combine (unsort + weighted scatter-add)
    rows = h.reshape(m.n_experts * C, D)
    padded = jnp.concatenate([rows, jnp.zeros((1, D), x.dtype)], axis=0)
    out_rows = padded[jnp.where(keep, dest, m.n_experts * C)]
    out = jnp.zeros((N, D), x.dtype)
    out = out.at[stok].add(out_rows * sg[:, None])
    return out.reshape(B, S, D)


def aux_load_balance_loss(logits: jax.Array, expert_idx: jax.Array,
                          n_experts: int, top_k: int) -> jax.Array:
    """Switch-style auxiliary loss (used in training examples)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(expert_idx, n_experts).sum(axis=1)
    ce = jnp.mean(one_hot, axis=0) / top_k
    return n_experts * jnp.sum(me * ce)
