"""Architecture registry: --arch <id> resolves here."""

from repro.configs.base import (  # noqa: F401
    SHAPES, SHAPES_BY_NAME, EncoderCfg, ModelConfig, MoECfg, RWKVCfg,
    ShapeSpec, SSMCfg,
)

from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.gemma_2b import CONFIG as _gemma
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.pixtral_12b import CONFIG as _pixtral

ARCHS = {
    c.name: c for c in (
        _qwen3_moe, _moonshot, _whisper, _deepseek, _gemma,
        _qwen2, _qwen15, _rwkv6, _zamba2, _pixtral,
    )
}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """Every (arch × shape) dry-run cell, with skips per DESIGN.md §4."""
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES:
            if shape.name == "long_500k" and not cfg.subquadratic:
                out.append((cfg, shape, "SKIP: full attention is quadratic; "
                            "500k dense KV decode infeasible (DESIGN.md §4)"))
            else:
                out.append((cfg, shape, None))
    return out
