"""zamba2-1.2b [arXiv:2411.15242; hf]: 38L Mamba2 backbone, d2048,
ssm_state=64, shared attention block (32H kv=32) every 6 layers, d_ff 8192.
Shared attention uses a sliding window at long context (long_500k cell)."""
from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8_192, vocab_size=32_000,
    mlp="swiglu", norm="rmsnorm", pos="rope",
    ssm=SSMCfg(state_dim=64, head_dim=64, expand=2),
    attn_every=6, sliding_window=4_096,
)
