"""deepseek-7b [arXiv:2401.02954; hf]: llama-arch 30L, d4096, 32H MHA,
d_ff 11008, vocab 102400."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11_008, vocab_size=102_400,
    mlp="swiglu", norm="rmsnorm", pos="rope",
)
