"""whisper-tiny [arXiv:2212.04356; unverified]: enc-dec, 4L each, d384, 6H,
d_ff 1536, vocab 51865, LayerNorm+GELU, learned positions, conv frontend
STUB (input_specs provides frame embeddings)."""
from repro.configs.base import EncoderCfg, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51_865,
    mlp="gelu", norm="layernorm", pos="learned",
    tie_embeddings=True,
    encoder=EncoderCfg(n_layers=4, n_frames=1500),
)
