"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified]: pixtral-ViT
frontend STUB (patch embeddings) + mistral-nemo decoder: 40L, d5120,
32H GQA kv=8, head_dim 128, d_ff 14336, vocab 131072."""
from repro.configs.base import EncoderCfg, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab_size=131_072,
    mlp="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
    encoder=EncoderCfg(n_layers=0, n_frames=1024),  # ViT STUB: 1024 patches
)
