"""Model configuration schema for all assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    """Mamba2-style SSD block parameters (zamba2)."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class RWKVCfg:
    """RWKV-6 'Finch' time-mix parameters."""
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class EncoderCfg:
    """Encoder tower for enc-dec (whisper) / VLM (pixtral) backbones.
    The modality frontend (conv / ViT patchifier) is a STUB: input_specs()
    provides precomputed frame/patch embeddings of width d_model."""
    n_layers: int
    n_frames: int          # encoder sequence length (audio frames / patches)
    is_causal: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // n_heads
    mlp: str = "swiglu"                   # swiglu | geglu | gelu
    qkv_bias: bool = False
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    pos: str = "rope"                     # rope | learned | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv: Optional[RWKVCfg] = None
    encoder: Optional[EncoderCfg] = None
    attn_every: int = 0                   # zamba2: shared attn block period
    sliding_window: int = 0               # 0 = full attention
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # training-shape metadata
    max_seq: int = 32_768

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k shape? (DESIGN.md §4)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 2,
            d_ff=128,
            vocab_size=256,
            head_dim=16 if self.head_dim else None,
            max_seq=128,
        )
        if self.moe:
            kw["moe"] = MoECfg(n_experts=4, top_k=2, d_ff_expert=64)
        if self.ssm:
            kw["ssm"] = SSMCfg(state_dim=8, head_dim=16, expand=2, chunk=16)
        if self.rwkv:
            kw["rwkv"] = RWKVCfg(head_dim=16, decay_lora=8, chunk=16)
        if self.encoder:
            kw["encoder"] = EncoderCfg(n_layers=2, n_frames=16,
                                       is_causal=self.encoder.is_causal)
        if self.attn_every:
            kw["attn_every"] = 2
        if self.sliding_window:
            kw["sliding_window"] = 64
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}
