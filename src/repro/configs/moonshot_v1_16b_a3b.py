"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf]: 48L, d2048,
16H GQA kv=16, MoE 64 experts top-6, d_ff_expert=1408, vocab 163840."""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163_840,
    mlp="swiglu", norm="rmsnorm", pos="rope", rope_theta=50_000.0,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408),
)
