"""gemma-2b [arXiv:2403.08295; hf]: 18L, d2048, 8H MQA (kv=1), head_dim=256,
GeGLU d_ff 16384, vocab 256000, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16_384, vocab_size=256_000,
    mlp="geglu", norm="rmsnorm", pos="rope",
    tie_embeddings=True,
)
