"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B; hf]: 94L, d4096, 64H GQA kv=4,
MoE 128 experts top-8, d_ff_expert=1536, vocab 151936."""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151_936,
    mlp="swiglu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=1536),
)
