"""qwen2-0.5b [arXiv:2407.10671; hf]: 24L, d896, 14H GQA kv=2, d_ff 4864,
vocab 151936, QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4_864, vocab_size=151_936,
    mlp="swiglu", norm="rmsnorm", pos="rope", qkv_bias=True,
    tie_embeddings=True,
)
