"""rwkv6-3b 'Finch' [arXiv:2404.05892; hf]: 32L, d2560, attention-free,
data-dependent decay, d_ff 8960, vocab 65536, head_dim 64 (40 heads)."""
from repro.configs.base import ModelConfig, RWKVCfg

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8_960, vocab_size=65_536,
    norm="layernorm", pos="none",
    rwkv=RWKVCfg(head_dim=64, decay_lora=64, chunk=128),
)
