"""qwen1.5-4b [hf:Qwen/Qwen1.5-0.5B; hf]: 40L, d2560, 20H MHA, d_ff 6912,
vocab 151936, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6_912, vocab_size=151_936,
    mlp="swiglu", norm="rmsnorm", pos="rope", qkv_bias=True,
)
