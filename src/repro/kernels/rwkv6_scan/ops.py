"""jit'd public wrapper for the chunked WKV6 kernel.

Handles a nonzero carried state by linearity: the kernel runs from zero
state, then the state0 contribution (a per-step decayed readout) and the
final-state fold-in are added outside — exact, and keeps the kernel simple.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import wkv6 as _kernel


def wkv6(r, k, v, w, u, *, chunk: int = 64, state0=None,
         force_interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_interpret):
        # jnp fallback used on CPU: the chunked reference in models.rwkv6
        from repro.models.rwkv6 import wkv_chunked
        return wkv_chunked(r, k, v, w, u, chunk=chunk, state0=state0)

    out, state = _kernel(r, k, v, w, u, chunk=chunk,
                         interpret=not on_tpu)
    if state0 is not None:
        logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0))
        cum = jnp.cumsum(logw, axis=1)               # (B,S,H,K) inclusive
        cum_excl = cum - logw
        r_dec = r.astype(jnp.float32) * jnp.exp(cum_excl)
        extra = jnp.einsum("bshk,bhkv->bshv", r_dec,
                           state0.astype(jnp.float32))
        out = (out.astype(jnp.float32) + extra).astype(out.dtype)
        total = jnp.exp(cum[:, -1])                  # (B,H,K)
        state = state + total[..., None] * state0.astype(jnp.float32)
    return out, state
