"""Chunked WKV6 linear-recurrence kernel (pl.pallas_call + BlockSpec).

Grid: (B·H, n_chunks) — the chunk axis is innermost, so the (K×K) f32 WKV
state lives in VMEM scratch and is carried across chunk steps (the TPU
idiom for a sequential scan: revisit the same core along the last grid
axis; CUDA implementations instead assign one SM per head and loop).

Per chunk (length c, head dim K):
  intra-chunk pair term via a (c, c) MXU matmul with per-channel pairwise
  decays, inter-chunk term via (c, K) × (K, K) matmul against the carried
  state, then the state update — everything in f32 inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, o_ref, state_out_ref,
                state_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)        # (c, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = logw_ref[0].astype(jnp.float32)  # (c, K)
    u = u_ref[0].astype(jnp.float32)        # (1, K) -> broadcast

    cum = jnp.cumsum(logw, axis=0)          # inclusive
    cum_excl = cum - logw
    state = state_ref[...]                  # (K, K)

    # inter-chunk: o_inter[t] = (r_t ⊙ exp(cum_excl_t)) @ S
    r_dec = r * jnp.exp(cum_excl)
    o_inter = jax.lax.dot(r_dec, state)     # (c, K)

    # intra-chunk: att[t,s] = Σ_k r_tk k_sk exp(cum_excl_t − cum_s), s < t
    dec = cum_excl[:, None, :] - cum[None, :, :]          # (c, c, K)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(tri[..., None], dec, -jnp.inf)
    att = jnp.einsum("tk,sk,tsk->ts", r, k, jnp.exp(dec))
    o_intra = jax.lax.dot(att, v)           # (c, K)

    # bonus: o_bonus[t] = (Σ_k r_tk u_k k_tk) v_t
    bonus = jnp.sum(r * u * k, axis=-1, keepdims=True)
    o_ref[0] = (o_inter + o_intra + bonus * v).astype(o_ref.dtype)

    # state update: S' = exp(cum_end) ⊙_k S + Σ_s exp(cum_end − cum_s) k_s v_s
    cum_end = cum[-1:, :]                   # (1, K)
    k_dec = k * jnp.exp(cum_end - cum)      # (c, K)
    state_ref[...] = (jnp.exp(cum_end[0])[:, None] * state
                      + jax.lax.dot(k_dec.T, v))

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_out_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, state0=None,
         interpret: bool = False):
    """r,k,v,w: (B,S,H,K); u: (H,K). Returns (out, final_state (B,H,K,K)).

    Note: kernel path starts from state0 == 0 (training path); a carried
    state0 is folded in by the ops wrapper before calling.
    """
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    def flat(a):
        return (a.transpose(0, 2, 1, 3).reshape(B * H, S, K))

    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-12, 1.0))
    uu = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    kernel = functools.partial(_wkv_kernel, chunk=chunk,
                               num_chunks=n_chunks)
    out, state = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, K), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, K, K), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, K), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, K), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(logw), uu)
    out = out.reshape(B, H, S, K).transpose(0, 2, 1, 3)
    return out, state.reshape(B, H, K, K)
