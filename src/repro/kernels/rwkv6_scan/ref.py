"""Pure-jnp oracle for the chunked WKV6 kernel: the naive sequential
recurrence (slow, exact)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state0=None):
    """r,k,v,w: (B,S,H,K); u: (H,K). Returns (out (B,S,H,K), state (B,H,K,K)).

    o_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    """
    B, S, H, K = r.shape
    state = (jnp.zeros((B, H, K, K), jnp.float32) if state0 is None
             else state0.astype(jnp.float32))

    def step(state, inp):
        rt, kt, vt, wt = (a.astype(jnp.float32) for a in inp)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum(
            "bhk,bhkv->bhv", rt,
            state + u.astype(jnp.float32)[None, :, :, None] * kv)
        new_state = wt[..., None] * state + kv
        return new_state, out

    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state
