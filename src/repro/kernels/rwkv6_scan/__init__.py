from repro.kernels.rwkv6_scan import ops, ref  # noqa: F401
