"""jit'd public wrapper for the flash-attention kernel.

On TPU this runs the Pallas kernel; everywhere else (this CPU container,
including the dry-run) it transparently uses interpret mode for tests or
the jnp reference for speed.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention as _kernel


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    force_interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_interpret:
        return _kernel(q, k, v, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       interpret=not on_tpu)
    return ref.attention_ref(q, k, v, causal=causal, window=window)
