"""Blocked online-softmax attention for TPU (pl.pallas_call + BlockSpec).

TPU adaptation (vs the CUDA FlashAttention algorithm): tiles are shaped for
the MXU (q/k blocks are multiples of 128 in the lane dim) and live in VMEM
via explicit BlockSpecs; the kv dimension is the innermost grid axis so the
f32 accumulators persist in VMEM scratch across kv steps (TPU grid steps on
the last axis revisit the same core — the Pallas-TPU idiom replacing CUDA's
per-CTA shared-memory loop). Causality is handled by skipping fully-masked
kv blocks via ``pl.when`` (no wasted MXU work past the diagonal).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, num_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip kv blocks strictly above the diagonal (causal) or out of window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run, k_start + block_k > q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)            # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq,)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(ki == num_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q,k,v: (B, S, H, hd) with H already GQA-expanded. -> (B, S, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = 1.0 / math.sqrt(hd)
    # layout: (B*H, S, hd) — head-major so each grid row owns one (b,h)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd)
    num_q = Sq // block_q
    num_k = Sk // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k=num_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            # f32 accumulators persist across the kv grid axis in VMEM
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
