"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: (B, S, H, hd) -> (B, S, H, hd). Full-precision softmax."""
    hd = q.shape[-1]
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
