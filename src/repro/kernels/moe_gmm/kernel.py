"""Grouped expert matmul (the MoE hot spot) as a tiled Pallas TPU kernel.

One grid row per (expert, token-tile, out-tile); the contraction (D) axis is
the innermost grid dim with an f32 VMEM accumulator, so each (bc × bf) MXU
tile is revisited across D steps — the TPU analogue of a CUDA split-K loop,
with BlockSpecs pinning every operand tile in VMEM. Tile defaults
(128×128×512) are MXU-aligned and keep the working set
(bc·bd + bd·bf + bc·bf floats) well under VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)   # (bc, bd)
    w = w_ref[0].astype(jnp.float32)   # (bd, bf)
    acc_ref[...] += jax.lax.dot(x, w)

    @pl.when(di == num_d - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_c", "block_f", "block_d", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   block_c: int = 128, block_f: int = 512,
                   block_d: int = 512, interpret: bool = False) -> jax.Array:
    """x: (E, C, D) @ w: (E, D, F) -> (E, C, F), expert-wise."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    num_d = D // block_d

    kernel = functools.partial(_gmm_kernel, num_d=num_d)
    return pl.pallas_call(
        kernel,
        grid=(E, C // block_c, F // block_f, num_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
