"""Pure-jnp oracle for the grouped expert FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_ffn_ref(eb, w_gate, w_up, w_down, *, mlp: str = "swiglu"):
    """eb: (E, C, D); w_gate/w_up: (E, D, F); w_down: (E, F, D)."""
    act = jax.nn.silu if mlp == "swiglu" else (
        lambda u: jax.nn.gelu(u, approximate=True))
    g = act(jnp.einsum("ecd,edf->ecf", eb, w_gate.astype(eb.dtype)))
    u = jnp.einsum("ecd,edf->ecf", eb, w_up.astype(eb.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(eb.dtype))


def grouped_matmul_ref(x, w):
    """x: (E, C, D), w: (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
