"""jit'd public wrapper: grouped expert FFN built on the Pallas GMM."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.moe_gmm import ref
from repro.kernels.moe_gmm.kernel import grouped_matmul as _gmm


def grouped_matmul(x, w, *, force_interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu or force_interpret:
        return _gmm(x, w, interpret=not on_tpu)
    return ref.grouped_matmul_ref(x, w)


def grouped_ffn(eb, w_gate, w_up, w_down, *, mlp: str = "swiglu",
                force_interpret: bool = False):
    act = jax.nn.silu if mlp == "swiglu" else (
        lambda u: jax.nn.gelu(u, approximate=True))
    g = act(grouped_matmul(eb, w_gate, force_interpret=force_interpret))
    u = grouped_matmul(eb, w_up, force_interpret=force_interpret)
    return grouped_matmul((g * u).astype(eb.dtype), w_down,
                          force_interpret=force_interpret)
