"""Supercomputer-center profiles (paper §4.2), calibrated for simulation.

HPC2n : 602 nodes × 2×14-core Xeon E5 v4  → 16 856 cores, Slurm 18.08
UPPMAX: 486 nodes × 2×10-core Xeon E5 v4  →  9 720 cores, Slurm 19.05

The background-workload parameters are calibrated so the *simulated* queue
waits land in the ranges the paper measured (Table 2):

  HPC2n  : small/medium jobs (≤112 cores) wait 0.4–1.5 h with σ comparable
           to the mean (high fragmentation / high variability),
  UPPMAX : large jobs (160–640 cores) wait 11–17 h with small σ (busy but
           stable — long-running wide jobs dominate).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CenterProfile:
    name: str
    nodes: int
    cores_per_node: int
    # Background (other users') load generator
    bg_arrival_rate: float      # jobs per second (Poisson)
    bg_cores_mean: float        # log-normal-ish job width
    bg_cores_sigma: float
    bg_duration_mean_s: float   # log-normal duration
    bg_duration_sigma: float
    bg_initial_backlog: int     # jobs already queued at t=0
    bg_burst_mean: float        # geometric mean jobs per arrival event
    scales: tuple[int, ...]     # paper's core scalings run at this center

    @property
    def total_cores(self) -> int:
        return self.nodes * self.cores_per_node


# Offered load = rate · E[cores] · E[duration] is kept at ≈95% of capacity
# so the queue is busy-but-stable; waits then come from the warm-start
# backlog + burstiness, matching Table 2's observed ranges.
HPC2N = CenterProfile(
    name="hpc2n",
    nodes=602,
    cores_per_node=28,
    bg_arrival_rate=1.0 / 85.0,  # ×burst 5 ⇒ ~112% offered load, bursty
    bg_cores_mean=3.4,          # e^3.4 ≈ 30 cores typical
    bg_cores_sigma=1.1,
    bg_duration_mean_s=7.6,     # e^7.6 ≈ 2000 s typical
    bg_duration_sigma=1.5,
    bg_initial_backlog=140,
    bg_burst_mean=5.0,          # array-job bursts ⇒ high wait variance
    scales=(28, 56, 112),
)

UPPMAX = CenterProfile(
    name="uppmax",
    nodes=486,
    cores_per_node=20,
    bg_arrival_rate=1.0 / 92.0,  # E[cores]≈41 · E[dur]≈2.2e4 s ⇒ ~95% load
    bg_cores_mean=3.0,
    bg_cores_sigma=1.2,
    bg_duration_mean_s=9.4,     # e^9.4 ≈ 12 100 s — long-running jobs
    bg_duration_sigma=1.1,
    bg_initial_backlog=750,
    bg_burst_mean=1.0,          # steady wide load ⇒ stable long waits
    scales=(160, 320, 640),
)

CENTERS = {c.name: c for c in (HPC2N, UPPMAX)}
