"""Experiment drivers reproducing the paper's evaluation (§4).

``run_table1``  — 54 runs: {Montage, BLAST, Statistics} × {BigJob, Per-Stage,
                  ASA} × 6 core scalings (28/56/112 @HPC2n, 160/320/640
                  @UPPMAX), plus the ASA-Naive sensitivity runs (§4.5).
``run_table2``  — prediction-accuracy: each job geometry submitted 60× with
                  1-minute gaps; real WT vs ASA WT vs perceived WT, hit/miss
                  ratios, OH losses.

ASA estimator state is shared across runs per (center, scale) job geometry,
exactly as §4.3 prescribes ("Algorithm 1's state is kept across different
runs").
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.sched.centers import CENTERS, CenterProfile
from repro.sched.queue_sim import QueueSim
from repro.sched.strategies import (
    ASAEstimator,
    RunMetrics,
    run_asa,
    run_bigjob,
    run_per_stage,
    run_pilot,
)
from repro.sched.workflows import WORKFLOWS, Workflow

WARMUP_S = 7200.0


def _fresh_sim(center: CenterProfile, seed: int) -> QueueSim:
    sim = QueueSim(center, seed=seed)
    sim.run_until(WARMUP_S)
    return sim


@dataclass
class Table1Result:
    runs: list[RunMetrics] = field(default_factory=list)

    def rows(self):
        return [
            dict(workflow=r.workflow, strategy=r.strategy, center=r.center,
                 scale=r.scale, twt_s=round(r.twt_s, 1),
                 makespan_s=round(r.makespan_s, 1),
                 core_hours=round(r.core_hours, 2),
                 oh_hours=round(r.oh_hours, 2))
            for r in self.runs
        ]


def run_table1(seed: int = 0, include_naive: bool = True,
               workflows: tuple[str, ...] = ("montage", "blast", "statistics"),
               n_warmup: int = 20,
               include_pilot: bool = False) -> Table1Result:
    out = Table1Result()
    estimators: dict[tuple[str, int], ASAEstimator] = {}
    for center in CENTERS.values():
        for scale in center.scales:
            est = estimators.setdefault(
                (center.name, scale),
                ASAEstimator(seed=hash((center.name, scale)) % (2**31)))
            # §4.3: Algorithm-1 state is kept across runs — enter the
            # measured runs warm, like the paper's estimators do
            wsim = _fresh_sim(center, seed + 17)
            for _ in range(n_warmup):
                j = wsim.submit(scale, 120.0, user="warm")
                wsim.run_until(wsim.now + 60.0)
                wsim.run_until_job_starts(j)
                est.learn(j.wait_time)
            for strategy in ("bigjob", "per_stage", "asa") + (
                    ("asa_naive",) if include_naive else ()) + (
                    ("pilot",) if include_pilot else ()):
                # identical background (same seed) for a fair comparison
                sim = _fresh_sim(center, seed)
                for wf_name in workflows:
                    wf = WORKFLOWS[wf_name]
                    if strategy == "bigjob":
                        m = run_bigjob(sim, wf, scale, center.name)
                    elif strategy == "per_stage":
                        m = run_per_stage(sim, wf, scale, center.name)
                    elif strategy == "pilot":
                        m = run_pilot(sim, wf, scale, center.name)
                    elif strategy == "asa":
                        m = run_asa(sim, wf, scale, center.name, est,
                                    use_dependencies=True)
                    else:
                        m = run_asa(sim, wf, scale, center.name, est,
                                    use_dependencies=False)
                    out.runs.append(m)
    return out


@dataclass
class Table2Row:
    workflow: str
    center: str
    scale: int
    real_wt_h: float
    real_wt_std_h: float
    asa_wt_h: float
    asa_wt_std_h: float
    pwt_h: float
    pwt_std_h: float
    hit_ratio: float
    miss_ratio: float
    oh_loss_h: float


def run_table2(seed: int = 0, n_submissions: int = 60,
               gap_s: float = 60.0, probe_duration_s: float = 120.0,
               n_warmup: int = 20, resub_threshold_s: float = 300.0,
               ) -> list[Table2Row]:
    rows: list[Table2Row] = []
    for center in CENTERS.values():
        for scale in center.scales:
            for wf_name, wf in WORKFLOWS.items():
                est = ASAEstimator(
                    seed=hash((center.name, scale, wf_name)) % (2**31))
                sim = _fresh_sim(center, seed + scale)
                # the paper keeps Algorithm-1 state across ALL prior runs
                # (§4.3); warm the estimator the same way before measuring
                for _ in range(n_warmup):
                    j = sim.submit(wf.peak_cores(scale), probe_duration_s,
                                   user="warm")
                    sim.run_until(sim.now + gap_s)
                    sim.run_until_job_starts(j)
                    est.learn(j.wait_time)
                real, pred, pwt = [], [], []
                hits = misses = 0
                oh_h = 0.0
                for k in range(n_submissions):
                    a = est.predict()
                    job = sim.submit(wf.peak_cores(scale), probe_duration_s,
                                     user="probe")
                    sim.run_until(sim.now + gap_s)
                    sim.run_until_job_ends(job)
                    w = job.wait_time
                    real.append(w)
                    pred.append(a)
                    # perceived wait: the fraction of the queue wait NOT
                    # hidden by the pro-active overlap window `a`
                    pwt.append(max(0.0, w - a))
                    if est.was_hit(a, w):
                        hits += 1
                    if a - w > resub_threshold_s:
                        # over-prediction big enough that the allocation
                        # would arrive early and need a re-submission
                        # (paper's miss; threshold = the strategies' naive
                        # idle threshold)
                        misses += 1
                        oh_h += wf.peak_cores(scale) * min(a - w, 3600.0) / 3600.0
                    est.learn(w)
                h = 3600.0
                rows.append(Table2Row(
                    workflow=wf_name, center=center.name, scale=scale,
                    real_wt_h=float(np.mean(real)) / h,
                    real_wt_std_h=float(np.std(real)) / h,
                    asa_wt_h=float(np.mean(pred)) / h,
                    asa_wt_std_h=float(np.std(pred)) / h,
                    pwt_h=float(np.mean(pwt)) / h,
                    pwt_std_h=float(np.std(pwt)) / h,
                    hit_ratio=hits / n_submissions,
                    miss_ratio=misses / n_submissions,
                    oh_loss_h=oh_h / n_submissions,
                ))
    return rows


def summarize_table1(res: Table1Result) -> dict[str, dict[str, float]]:
    """Normalized averages per strategy (paper's 'Normalized Average' rows):
    each metric normalized to the best strategy for that (workflow, scale)."""
    strategies = sorted({r.strategy for r in res.runs})
    keys = sorted({(r.workflow, r.center, r.scale) for r in res.runs})
    agg = {s: {"twt": [], "makespan": [], "ch": []} for s in strategies}
    for key in keys:
        group = [r for r in res.runs
                 if (r.workflow, r.center, r.scale) == key]
        if not group:
            continue
        # floor the normalizers: sub-minute waits are noise, not signal
        best_twt = max(min(r.twt_s for r in group), 60.0)
        best_mk = max(min(r.makespan_s for r in group), 60.0)
        best_ch = max(min(r.core_hours for r in group), 1.0)
        for r in group:
            agg[r.strategy]["twt"].append(max(r.twt_s, 60.0) / best_twt)
            agg[r.strategy]["makespan"].append(r.makespan_s / best_mk)
            agg[r.strategy]["ch"].append(r.core_hours / best_ch)
    return {
        s: {k: float(np.mean(v)) - 1.0 for k, v in d.items()}
        for s, d in agg.items()
    }
