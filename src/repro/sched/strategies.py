"""The three submission strategies of §2.2/§4.1 + ASA-Naive (§4.5).

Each strategy drives a QueueSim interactively and returns RunMetrics. ASA
carries a (shared, cross-run) estimator state per job geometry, exactly as
the paper shares Algorithm-1 state across runs (§4.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import asa
from repro.core.bins import make_bins, nearest_bin
from repro.core.losses import zero_one
from repro.sched.queue_sim import QueueSim
from repro.sched.workflows import Workflow

# §4.5 ASA-Naive miss handling (single source of truth — xsim mirrors
# these; the cross-engine differential tests pin the shared values)
NAIVE_IDLE_THRESHOLD_S = 300.0   # idle the early allocation up to this gap
NAIVE_CANCEL_LATENCY_S = 60.0    # charged OH when cancelling instead

# Pilot-job policy (id 5): one peak-cores allocation, stages cycled inside
# it by an internal task scheduler (the allocation-scheduler pilot model).
# The pilot queues ONCE (BigJob-like wait) but pays for its startup and
# the per-stage dispatch latency of the internal scheduler on top of the
# BigJob packing waste. Single source of truth — xsim mirrors these.
PILOT_STARTUP_S = 60.0           # pilot bootstrap before the first task
PILOT_TASK_LATENCY_S = 1.0       # internal dispatch latency per stage


@dataclass
class RunMetrics:
    workflow: str
    strategy: str
    center: str
    scale: int
    twt_s: float = 0.0          # total (perceived, for ASA) waiting time
    makespan_s: float = 0.0
    core_hours: float = 0.0     # charged core-hours (incl. OH)
    oh_hours: float = 0.0       # over-allocation (idle) core-hour loss
    hits: int = 0               # stage submissions whose estimate was optimal
    misses: int = 0             # over-predictions forcing resubmission/idle
    stage_waits: list[float] = field(default_factory=list)
    pred_waits: list[float] = field(default_factory=list)
    real_waits: list[float] = field(default_factory=list)


@dataclass
class ASAEstimator:
    """One Algorithm-1 state per job geometry, persisted across runs."""

    m: int = 53
    policy: str = "tuned"
    repetitions: int = 50
    gamma: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        self.bins = jnp.asarray(make_bins(self.m), dtype=jnp.float32)
        self.state = asa.init(self.m, jax.random.PRNGKey(self.seed))

    def predict(self) -> float:
        """Sample a waiting-time estimate according to the current policy."""
        if self.policy == "greedy":
            a = asa.greedy_action(self.state)
        else:
            self.state, a = asa.sample_action(self.state)
        return float(self.bins[a])

    def learn(self, true_wait_s: float) -> None:
        lv = zero_one(self.bins, jnp.float32(max(true_wait_s, 1.0)))
        g = jnp.asarray(self.gamma, jnp.float32)
        self.state, _ = asa.step(
            self.state, lv, g, policy=self.policy,
            repetitions=self.repetitions)

    def was_hit(self, predicted_s: float, true_wait_s: float) -> bool:
        b = np.asarray(self.bins)
        return bool(
            nearest_bin(b, predicted_s) == nearest_bin(b, max(true_wait_s, 1.0)))


def run_bigjob(sim: QueueSim, wf: Workflow, scale: int,
               center: str) -> RunMetrics:
    m = RunMetrics(wf.name, "bigjob", center, scale)
    t_total = wf.total_exec(scale)
    submit_t = sim.now
    job = sim.submit(wf.peak_cores(scale), t_total, user="wf")
    sim.run_until_job_ends(job)
    m.twt_s = job.wait_time
    m.stage_waits = [job.wait_time]
    m.makespan_s = job.end_time - submit_t
    m.core_hours = wf.bigjob_core_seconds(scale) / 3600.0
    return m


def pilot_duration(wf: Workflow, scale: int) -> float:
    """Walltime of the pilot allocation: the serialized stage work plus
    the pilot's bootstrap and per-stage internal dispatch latency."""
    return (wf.total_exec(scale) + PILOT_STARTUP_S
            + len(wf.stages) * PILOT_TASK_LATENCY_S)


def pilot_waste_cs(wf: Workflow, scale: int) -> float:
    """Over-allocation core-seconds of the pilot: everything the
    peak-cores allocation charges beyond the stages' useful work
    (BigJob-style packing waste + startup + dispatch latency)."""
    return (wf.peak_cores(scale) * pilot_duration(wf, scale)
            - wf.core_seconds(scale))


def run_pilot(sim: QueueSim, wf: Workflow, scale: int,
              center: str) -> RunMetrics:
    """Pilot-job policy: queue one peak-cores allocation, cycle every
    stage inside it. One queue wait (BigJob's bracket from below on TWT),
    BigJob's packing waste plus the pilot overheads on core-hours —
    the natural rival bracketing ASA between BigJob and Per-Stage."""
    m = RunMetrics(wf.name, "pilot", center, scale)
    dur = pilot_duration(wf, scale)
    submit_t = sim.now
    job = sim.submit(wf.peak_cores(scale), dur, user="wf")
    sim.run_until_job_ends(job)
    m.twt_s = job.wait_time
    m.stage_waits = [job.wait_time]
    m.makespan_s = job.end_time - submit_t
    m.core_hours = wf.peak_cores(scale) * dur / 3600.0
    m.oh_hours = pilot_waste_cs(wf, scale) / 3600.0
    return m


def run_per_stage(sim: QueueSim, wf: Workflow, scale: int,
                  center: str) -> RunMetrics:
    m = RunMetrics(wf.name, "per_stage", center, scale)
    submit_t = sim.now
    end_prev = None
    for st in wf.stages:
        job = sim.submit(st.cores(scale), st.duration(scale), user="wf")
        sim.run_until_job_ends(job)
        m.stage_waits.append(job.wait_time)
        m.twt_s += job.wait_time
        end_prev = job.end_time
    m.makespan_s = end_prev - submit_t
    m.core_hours = wf.core_seconds(scale) / 3600.0
    return m


def run_asa(
    sim: QueueSim,
    wf: Workflow,
    scale: int,
    center: str,
    est: ASAEstimator,
    *,
    use_dependencies: bool = True,
    naive_idle_threshold_s: float = NAIVE_IDLE_THRESHOLD_S,
    naive_cancel_latency_s: float = NAIVE_CANCEL_LATENCY_S,
) -> RunMetrics:
    """ASA pro-active submission (§3.2, Fig. 4).

    Submissions CASCADE on expected end-dates: stage y's job is submitted at
    ``E[end_{y-1}] − a_y`` where ``E[end_{y-1}]`` chains the *estimated*
    wait of stage y−1 (sampled at its own submission) plus its execution
    time, and ``a_y`` is ASA's sampled wait estimate for stage y. This is
    Fig. 4's "two concurrent pro-active submissions within ongoing stages":
    several stage jobs may be queued simultaneously, so a 15-hour queue wait
    for stage y overlaps stage y−1's own wait + execution, not merely its
    execution.

    With ``use_dependencies`` (default ASA) each job carries a Slurm-style
    afterok dependency on its predecessor: it accrues queue position from
    submission but cannot start early — over-predictions cost nothing
    (OH = 0) and PWT_y = start_y − end_{y-1}.

    ASA-Naive (no dependency support, §4.5): an allocation granted *before*
    stage y−1 finishes either idles (short gaps, charged as OH core-hours)
    or is canceled and re-submitted once the predecessor actually ends (long
    gaps — the paper's Montage-112 Naive case), incurring an extra
    perceived wait.
    """
    name = "asa" if use_dependencies else "asa_naive"
    m = RunMetrics(wf.name, name, center, scale)
    t0 = sim.now
    s = len(wf.stages)
    jobs: list = [None] * s          # final (possibly re-submitted) job per stage
    final: list = [False] * s        # stage job settled (started its compute)
    hold_s = [0.0] * s               # idle hold before compute (naive)

    def duration(y: int) -> float:
        return wf.stages[y].duration(scale)

    def cores(y: int) -> int:
        return wf.stages[y].cores(scale)

    def on_started(y: int):
        """Learning + naive early-start handling, at the job's start event."""
        def hook(j):
            prev = jobs[y - 1] if y > 0 else None
            prev_running_end = (
                None if prev is None or prev.start_time is None
                else prev.start_time + hold_s[y - 1] + duration(y - 1))
            early = (None if y == 0 else
                     (float("inf") if prev_running_end is None
                      else prev_running_end - sim.now))
            if (not use_dependencies and early is not None and early > 0):
                m.misses += 1
                if early <= naive_idle_threshold_s:
                    hold_s[y] = early
                    m.oh_hours += j.cores * early / 3600.0
                    final[y] = True
                    est.learn(j.wait_time)
                else:
                    # cancel now; re-submit when the predecessor really ends
                    m.oh_hours += j.cores * naive_cancel_latency_s / 3600.0
                    sim.cancel(j)

                    def resubmit(pj):
                        nj = sim.submit(cores(y), duration(y), user="wf")
                        jobs[y] = nj
                        sim.on_start(nj, on_started(y))

                    if prev is not None and prev.id in sim.finished:
                        resubmit(prev)
                    elif prev is not None:
                        sim.on_end(prev, resubmit)
                return
            final[y] = True
            est.learn(j.wait_time)
        return hook

    def schedule_stage(y: int, expected_prev_end: float, dep_id) -> None:
        a = est.predict()
        m.pred_waits.append(a)
        submit_at = max(sim.now, expected_prev_end - a)

        def do_submit():
            dep = dep_id if use_dependencies else None
            j = sim.submit(cores(y), duration(y), depend_on=dep, user="wf")
            jobs[y] = j
            sim.on_start(j, on_started(y))
            expected_end = max(sim.now + a, expected_prev_end) + duration(y)
            if y + 1 < s:
                schedule_stage(y + 1, expected_end, j.id)

        sim.at(submit_at, do_submit)

    # stage 0: plain submission, no overlap possible
    j0 = sim.submit(cores(0), duration(0), user="wf")
    jobs[0] = j0
    sim.on_start(j0, on_started(0))
    a0 = est.predict()  # expected wait for the bookkeeping chain
    if s > 1:
        schedule_stage(1, t0 + a0 + duration(0), j0.id)

    # drive the sim until every stage's (final) job has finished
    for y in range(s):
        while jobs[y] is None or not final[y]:
            sim._step()
        sim.run_until_job_ends(jobs[y])

    # ---- metrics from the settled timeline
    logical_end = None
    for y in range(s):
        j = jobs[y]
        start = j.start_time + hold_s[y]
        pwt = j.wait_time if y == 0 else max(0.0, j.start_time - logical_end)
        m.stage_waits.append(pwt)
        m.twt_s += pwt
        m.real_waits.append(j.wait_time)
        if y > 0 and est.was_hit(m.pred_waits[y - 1], j.wait_time):
            m.hits += 1
        logical_end = (start if y == 0 else max(start, logical_end)) + duration(y)
    sim.run_until(logical_end)
    m.makespan_s = logical_end - t0
    m.core_hours = wf.core_seconds(scale) / 3600.0 + m.oh_hours
    return m
