"""repro.sched — batch-queue substrate: simulator, centers, workflows,
submission strategies (BigJob / Per-Stage / ASA / ASA-Naive)."""
