"""The paper's three scientific workflows (§4.3), as stage profiles.

Stage structure follows the paper exactly; per-stage durations are
calibrated to the paper's 28-core execution times (Table 1 makespans minus
waits) with Amdahl-style scaling exponents chosen per the paper's
scalability statements:

  * Montage   — 9 stages, "not a scalable application" (α small): first two
                and fifth parallel, plus the background-apply stage; third &
                fourth and last three sequential.
  * BLAST     — 2 stages, "very scalable" (α near 1): one wide parallel
                match stage, one sequential merge.
  * Statistics— 4 stages, network-intensive (α mid): two sequential and two
                parallel stages, intertwined.

Sequential stages use SEQ_CORES cores (one resource unit in the paper's
terms; a node's worth of cores would also be defensible — metrics are
dominated by the parallel stages either way).
"""

from __future__ import annotations

from dataclasses import dataclass

SEQ_CORES = 4
BASE_CORES = 28  # durations are specified at the paper's smallest scaling


@dataclass(frozen=True)
class Stage:
    name: str
    parallel: bool
    base_t: float          # seconds at BASE_CORES (parallel) or fixed (seq)
    alpha: float = 0.0     # Amdahl exponent: t(n) = base_t * (BASE/n)^alpha

    def duration(self, n_cores: int) -> float:
        if not self.parallel:
            return self.base_t
        return self.base_t * (BASE_CORES / n_cores) ** self.alpha

    def cores(self, n_cores: int) -> int:
        return n_cores if self.parallel else SEQ_CORES


@dataclass(frozen=True)
class Workflow:
    name: str
    stages: tuple[Stage, ...]

    def total_exec(self, n: int) -> float:
        return sum(s.duration(n) for s in self.stages)

    def peak_cores(self, n: int) -> int:
        return max(s.cores(n) for s in self.stages)

    def core_seconds(self, n: int) -> float:
        """Eq. (2): Σ t_i · n_i — the Per-Stage (optimal) core usage."""
        return sum(s.duration(n) * s.cores(n) for s in self.stages)

    def bigjob_core_seconds(self, n: int) -> float:
        """Eq. (1): n · Σ t_i."""
        return self.peak_cores(n) * self.total_exec(n)


MONTAGE = Workflow(
    "montage",
    (
        Stage("mProject-a", True, 300.0, 0.25),
        Stage("mProject-b", True, 200.0, 0.25),
        Stage("mImgtbl", False, 150.0),
        Stage("mOverlaps", False, 100.0),
        Stage("mDiffFit", True, 250.0, 0.25),
        Stage("mBackground", True, 120.0, 0.25),
        Stage("mConcatFit", False, 60.0),
        Stage("mBgModel", False, 60.0),
        Stage("mAdd", False, 80.0),
    ),
)

BLAST = Workflow(
    "blast",
    (
        Stage("match", True, 2500.0, 0.80),
        Stage("merge", False, 180.0),
    ),
)

STATISTICS = Workflow(
    "statistics",
    (
        Stage("ingest", False, 300.0),
        Stage("stats-a", True, 2400.0, 0.45),
        Stage("reshard", False, 300.0),
        Stage("stats-b", True, 2400.0, 0.45),
    ),
)

WORKFLOWS = {w.name: w for w in (MONTAGE, BLAST, STATISTICS)}
