"""Discrete-event batch-queue simulator (Slurm-like: FCFS + EASY backfill).

This is the substrate under every Table-1/Table-2 experiment: the container
has no batch system, so the two centers are simulated (DESIGN.md §8). The
simulator supports everything the strategies need:

  * interactive submission mid-run (ASA's pro-active submissions),
  * job dependencies (``depend_on`` — Slurm ``--dependency=afterok``): the
    job accrues queue position from submission but cannot start before its
    dependency completes,
  * cancellation + resubmission (ASA-Naive miss handling),
  * timed user callbacks (``at``) and job start/end hooks,
  * a calibrated background workload of "other users" (Poisson arrivals,
    log-normal widths/durations, warm-start backlog + initially running mix).

Cores are fungible (node-packing is not modelled); the paper's metrics are
all core-granular so this loses nothing for the reproduction.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.sched.centers import CenterProfile


@dataclass
class Job:
    id: int
    cores: int
    duration: float
    submit_time: float
    depend_on: Optional[int] = None
    user: str = "bg"
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    canceled: bool = False

    @property
    def wait_time(self) -> float:
        assert self.start_time is not None
        return self.start_time - self.submit_time


class QueueSim:
    def __init__(self, profile: CenterProfile, seed: int = 0,
                 bg_horizon: float = float("inf")):
        self.profile = profile
        self.rng = np.random.default_rng(seed)
        self.now = 0.0
        self.free_cores = profile.total_cores
        self.jobs: dict[int, Job] = {}
        self.queue: list[int] = []          # FCFS order (job ids)
        self.running: list[tuple[float, int]] = []  # heap (end_time, id)
        self._events: list[tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._ids = itertools.count(1)
        self._start_hooks: dict[int, list[Callable[[Job], None]]] = {}
        self._end_hooks: dict[int, list[Callable[[Job], None]]] = {}
        self.finished: set[int] = set()
        self._bg_horizon = bg_horizon
        self._warm_start()
        self._push(self._next_bg_gap(), "bg_arrival", None)

    # ------------------------------------------------------------- events
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule a user callback at absolute sim-time t."""
        self._push(max(t, self.now), "user", fn)

    def on_start(self, job: Job, fn: Callable[[Job], None]) -> None:
        if job.start_time is not None:  # already started: fire immediately
            fn(job)
            return
        self._start_hooks.setdefault(job.id, []).append(fn)

    def on_end(self, job: Job, fn: Callable[[Job], None]) -> None:
        if job.id in self.finished:  # already done: fire immediately
            fn(job)
            return
        self._end_hooks.setdefault(job.id, []).append(fn)

    # ------------------------------------------------------- background
    def _next_bg_gap(self) -> float:
        return self.now + self.rng.exponential(1.0 / self.profile.bg_arrival_rate)

    def _bg_job_shape(self) -> tuple[int, float]:
        p = self.profile
        cores = int(np.clip(np.exp(self.rng.normal(p.bg_cores_mean, p.bg_cores_sigma)),
                            1, p.total_cores // 2))
        dur = float(np.clip(np.exp(self.rng.normal(p.bg_duration_mean_s,
                                                   p.bg_duration_sigma)),
                            30.0, 7 * 86400.0))
        return cores, dur

    def _warm_start(self) -> None:
        """Fill the machine with running jobs and pre-queue a backlog."""
        p = self.profile
        used = 0
        while used < int(p.total_cores * 0.97):
            cores, dur = self._bg_job_shape()
            cores = min(cores, p.total_cores - used)
            j = Job(next(self._ids), cores, dur, submit_time=0.0)
            # residual duration: job started some time ago
            j.start_time = 0.0
            j.end_time = self.rng.uniform(0.05, 1.0) * dur
            self.jobs[j.id] = j
            heapq.heappush(self.running, (j.end_time, j.id))
            self._push(j.end_time, "job_end", j.id)
            used += cores
        self.free_cores = p.total_cores - used
        for _ in range(p.bg_initial_backlog):
            cores, dur = self._bg_job_shape()
            j = Job(next(self._ids), cores, dur, submit_time=0.0)
            self.jobs[j.id] = j
            self.queue.append(j.id)

    # ------------------------------------------------------------ submit
    def submit(self, cores: int, duration: float,
               depend_on: Optional[int] = None, user: str = "me") -> Job:
        if cores > self.profile.total_cores:
            raise ValueError(
                f"job wants {cores} cores > machine {self.profile.total_cores}")
        j = Job(next(self._ids), cores, float(duration), self.now,
                depend_on=depend_on, user=user)
        self.jobs[j.id] = j
        self.queue.append(j.id)
        self._schedule_pass()
        return j

    def cancel(self, job: Job) -> None:
        job.canceled = True
        if job.id in self.queue:
            self.queue.remove(job.id)
        elif job.start_time is not None and job.id not in self.finished:
            # running: free its cores immediately
            self.free_cores += job.cores
            self.running = [(t, i) for t, i in self.running if i != job.id]
            heapq.heapify(self.running)
            job.end_time = self.now
            self._schedule_pass()

    # --------------------------------------------------------- scheduler
    def _eligible(self, j: Job) -> bool:
        if j.canceled or j.start_time is not None:
            return False
        if j.depend_on is not None:
            dep = self.jobs[j.depend_on]
            if dep.end_time is None or dep.end_time > self.now:
                return False
        return True

    def _start(self, j: Job) -> None:
        j.start_time = self.now
        j.end_time = self.now + j.duration
        self.free_cores -= j.cores
        heapq.heappush(self.running, (j.end_time, j.id))
        self.queue.remove(j.id)
        self._push(j.end_time, "job_end", j.id)
        for fn in self._start_hooks.pop(j.id, []):
            fn(j)

    def _schedule_pass(self) -> None:
        """FCFS + EASY backfill over the eligible queue."""
        # 1. start jobs from the front while they fit
        while True:
            head = None
            for jid in self.queue:
                j = self.jobs[jid]
                if self._eligible(j):
                    head = j
                    break
            if head is None:
                return
            if head.cores <= self.free_cores:
                self._start(head)
                continue
            break
        # 2. EASY backfill: reservation for `head`, fill around it.
        # Like Slurm's bf_max_job_test, only the first BF_MAX queued jobs
        # are considered — keeps each pass O(BF_MAX) on deep queues.
        BF_MAX = 96
        shadow_time, extra = self._reservation(head)
        for jid in list(self.queue[:BF_MAX]):
            # start hooks may cancel/submit re-entrantly (ASA-Naive
            # resubmission): re-check membership against the LIVE queue
            if jid not in self.queue:
                continue
            j = self.jobs[jid]
            if j is head or j.start_time is not None or not self._eligible(j):
                continue
            if j.cores > self.free_cores:
                continue
            fits_before_shadow = self.now + j.duration <= shadow_time
            fits_in_extra = j.cores <= extra
            if fits_before_shadow or fits_in_extra:
                self._start(j)
                if fits_in_extra:
                    extra -= j.cores

    def _reservation(self, head: Job) -> tuple[float, int]:
        """When can `head` start, and how many cores are spare at that time."""
        free = self.free_cores
        ends = sorted(self.running)
        for end_t, jid in ends:
            if jid in self.finished or self.jobs[jid].canceled:
                continue
            free += self.jobs[jid].cores
            if free >= head.cores:
                return end_t, free - head.cores
        return float("inf"), 0

    # ------------------------------------------------------------- loop
    def run_until(self, t: float) -> None:
        while self._events and self._events[0][0] <= t:
            self._step()
        self.now = max(self.now, t)

    def run_until_job_starts(self, job: Job,
                             hard_limit: float = 90 * 86400.0) -> None:
        while job.start_time is None and not job.canceled:
            if not self._events or self.now > hard_limit:
                raise RuntimeError(f"job {job.id} never started (sim starved)")
            self._step()

    def run_until_job_ends(self, job: Job, hard_limit: float = 90 * 86400.0) -> None:
        while job.id not in self.finished and not job.canceled:
            if not self._events or self.now > hard_limit:
                raise RuntimeError(f"job {job.id} never finished (sim starved)")
            self._step()

    def _step(self) -> None:
        t, _, kind, payload = heapq.heappop(self._events)
        self.now = max(self.now, t)
        if kind == "job_end":
            j = self.jobs[payload]
            if j.canceled:
                return
            self.finished.add(j.id)
            self.free_cores += j.cores
            # lazy cleanup of the running heap (ended jobs leave the top)
            while self.running and self.running[0][1] in self.finished:
                heapq.heappop(self.running)
            for fn in self._end_hooks.pop(j.id, []):
                fn(j)
            self._schedule_pass()
        elif kind == "bg_arrival":
            if self.now < self._bg_horizon:
                burst = self.rng.geometric(1.0 / self.profile.bg_burst_mean)
                for _ in range(int(burst)):
                    cores, dur = self._bg_job_shape()
                    jb = Job(next(self._ids), cores, dur, self.now)
                    self.jobs[jb.id] = jb
                    self.queue.append(jb.id)
                self._schedule_pass()
            self._push(self._next_bg_gap(), "bg_arrival", None)
        elif kind == "user":
            payload()
            self._schedule_pass()

    # --------------------------------------------------------- queries
    def utilization(self) -> float:
        return 1.0 - self.free_cores / self.profile.total_cores
