"""repro.parallel — sharding rules + collective analysis."""
