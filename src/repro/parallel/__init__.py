"""repro.parallel — sharding rules, collective analysis, and the
scenario-axis (fleet) data-parallel helpers used by xsim's sharded
sweeps (see ``repro.parallel.fleet``)."""
