"""Divisibility-aware FSDP × TP × EP sharding rules.

Mesh axes:
  * ``model``          — tensor/expert parallel (16-way on the target pod)
  * ``data``           — data + ZeRO-3 (FSDP) parameter sharding
  * ``pod`` (optional) — multi-pod extension of the data/FSDP dimension

Every parameter shards its *compute* dim (heads / d_ff / experts / d_inner)
over ``model`` and its d_model (or vocab) dim over the FSDP axes — each only
when divisible, else that dim is replicated (e.g. gemma-2b's 8 heads on a
16-way model axis fall back to replicated heads, TP then comes from its
16384-wide d_ff). Stacked-layer params get a leading ``None`` for the L dim.

The rules are *name-pattern driven* over the parameter tree paths, with a
size-checked fallback, so new modules compose without touching the engine.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(dim: int, n: int) -> bool:
    return n > 1 and dim % n == 0


class ShardingRules:
    """Maps parameter-tree paths to PartitionSpecs for a given mesh."""

    # dims named by their role; rule = {path-substring: (role per dim)}
    # roles: 'd' -> FSDP axes, 'm' -> model axis, '.' -> replicated
    RULES: list[tuple[str, str]] = [
        ("embed/table", "md"),      # (V, D): vocab->model, d_model->fsdp
        ("embed/pos", ".d"),
        ("lm_head", "dm"),          # (D, V)
        ("enc_pos", ".d"),
        ("attn/wq", "dm."),         # (D, H, hd)
        ("attn/wk", "dm."),
        ("attn/wv", "dm."),
        ("attn/wo", "m.d"),         # (H, hd, D)
        ("attn/bq", "m."),
        ("attn/bk", "m."),
        ("attn/bv", "m."),
        ("xattn/wq", "dm."),
        ("xattn/wk", "dm."),
        ("xattn/wv", "dm."),
        ("xattn/wo", "m.d"),
        ("xattn/bq", "m."),
        ("xattn/bk", "m."),
        ("xattn/bv", "m."),
        ("shared_attn/wq", "dm."),
        ("shared_attn/wk", "dm."),
        ("shared_attn/wv", "dm."),
        ("shared_attn/wo", "m.d"),
        ("mlp/w_gate", "dm"),       # (D, F)
        ("mlp/w_up", "dm"),
        ("mlp/w_down", "md"),       # (F, D)
        ("mlp/b_up", "m"),
        ("mlp/b_down", "d"),
        ("shared_mlp/w_gate", "dm"),
        ("shared_mlp/w_up", "dm"),
        ("shared_mlp/w_down", "md"),
        ("moe/router", "d."),       # (D, E): router replicated over model
        ("moe/w_gate", "md."),      # (E, D, F): EP on experts
        ("moe/w_up", "md."),
        ("moe/w_down", "m.d"),      # (E, F, D)
        # rwkv6 time-mix: (D, D) projections — out-dim to model
        ("time_mix/wr", "dm"),
        ("time_mix/wk", "dm"),
        ("time_mix/wv", "dm"),
        ("time_mix/wg", "dm"),
        ("time_mix/wo", "md"),
        ("time_mix/decay_A", "d."),
        ("time_mix/decay_B", ".d"),
        ("time_mix/bonus_u", "m."),
        ("channel_mix/w_in", "dm"),
        ("channel_mix/w_out", "md"),
        # mamba2: d_inner/heads to model, d_model to fsdp
        ("mamba/w_in_z", "dm"),
        ("mamba/w_in_x", "dm"),
        ("mamba/w_in_B", "dm."),    # (D, H, N)
        ("mamba/w_in_C", "dm."),
        ("mamba/w_in_dt", "dm"),
        ("mamba/dt_bias", "m"),
        ("mamba/A_log", "m"),
        ("mamba/D_skip", "m."),
        ("mamba/conv_x", ".m"),     # (W, d_inner)
        ("mamba/out_norm", "m"),
        ("mamba/w_out", "md"),
    ]

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.fsdp = fsdp_axes(mesh)
        self.n_fsdp = axis_size(mesh, self.fsdp)
        self.n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def _role_axis(self, role: str, dim: int):
        if role == "m" and _div(dim, self.n_model):
            return "model"
        if role == "d" and self.fsdp and _div(dim, self.n_fsdp):
            return self.fsdp
        return None

    def spec_for(self, path: str, shape: tuple[int, ...]) -> P:
        """path: '/'-joined tree path; leading 'layers/' handled (stacked)."""
        stacked = path.startswith("layers/") or "/layers/" in path
        core_shape = shape[1:] if stacked else shape
        spec: Optional[tuple] = None
        for pat, roles in self.RULES:
            if pat in path:
                if len(roles) != len(core_shape):
                    continue
                spec = tuple(self._role_axis(r, d)
                             for r, d in zip(roles, core_shape))
                break
        if spec is None:
            # fallback: replicate small tensors; for ≥2D try largest-dim FSDP
            if len(core_shape) >= 2 and max(core_shape) >= 1024:
                spec = tuple(
                    (self.fsdp if (d == max(core_shape)
                                   and self.fsdp
                                   and _div(d, self.n_fsdp)) else None)
                    for d in core_shape)
            else:
                spec = tuple(None for _ in core_shape)
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    def tree_specs(self, params) -> object:
        """PartitionSpec pytree matching ``params``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            pstr = "/".join(_key_str(k) for k in path)
            specs.append(self.spec_for(pstr, leaf.shape))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def tree_shardings(self, params):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.tree_specs(params),
                            is_leaf=lambda x: isinstance(x, P))

    # ---------------- activation/batch shardings
    def batch_spec(self, batch_size: int, ndim: int) -> P:
        ax = self.fsdp if (self.fsdp and _div(batch_size, self.n_fsdp)) else None
        return P(ax, *([None] * (ndim - 1)))

    def kv_cache_spec(self, batch: int, n_kv: int, stacked: bool = True) -> P:
        """(L, B, S, KV, hd) or (B, S, KV, hd)."""
        b_ax = self.fsdp if (self.fsdp and _div(batch, self.n_fsdp)) else None
        h_ax = "model" if _div(n_kv, self.n_model) else None
        core = (b_ax, None, h_ax, None)
        return P(*(((None,) + core) if stacked else core))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
