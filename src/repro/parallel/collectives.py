"""Collective-traffic analysis of lowered/compiled HLO.

``collective_bytes`` sums operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in the (partitioned,
per-device) HLO text — the §Roofline collective term's numerator.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[94,4096,8192]{...} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _size_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind byte totals + counts from HLO text."""
    bytes_by_kind: dict[str, int] = defaultdict(int)
    count_by_kind: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        # skip the -done halves of async pairs (same tensor twice)
        if m.group(0).rstrip("(").endswith("-done"):
            continue
        bytes_by_kind[kind] += _size_bytes(dtype, dims)
        count_by_kind[kind] += 1
    return {
        "bytes_by_kind": dict(bytes_by_kind),
        "count_by_kind": dict(count_by_kind),
        "total_bytes": sum(bytes_by_kind.values()),
        "total_count": sum(count_by_kind.values()),
    }
