"""Scenario-axis (fleet) data parallelism helpers.

The xsim sweep is embarrassingly parallel over its batch axis — each
scenario is an independent ``lax.scan`` — so scaling past one device is a
pure data split: ``shard_map`` the leading axis of the batched
``ScenarioState`` over a 1-D ``scenarios`` mesh, replicate the (small) RL
``params`` pytree, and gather the per-scenario results. This module holds
the mesh-agnostic plumbing shared by ``xsim.events.sharded_sweep``:

* ``pad_batch`` — pad a batched pytree's leading axis up to a multiple of
  the shard count (by repeating row 0: a real, runnable scenario, so pad
  rows never produce NaNs or divergent control flow) + the validity mask.
  With the drain-aware chunked sweep (``events.simulate``), pad rows
  participate in the per-device early-exit vote like any other lane: the
  pad lanes land on the *last* shard, so if scenario 0 drains later than
  that shard's real rows, padding can extend the last device's chunk
  count (never its results — drained lanes step as exact no-ops and the
  pad rows are sliced off). Worst-case waste is unchanged:
  ``n_shards − 1`` scenario slots;
* ``shard_spec`` / ``replicated_spec`` — the two PartitionSpecs a fleet
  sweep ever needs;
* ``unpad`` — slice the gathered result back to the real batch.

The mesh itself comes from ``repro.launch.mesh.make_scenarios_mesh`` (a
function, not a constant, so importing never touches jax device state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

SCENARIO_AXIS = "scenarios"


def shard_spec() -> PartitionSpec:
    """Leading axis on the ``scenarios`` mesh axis, rest replicated."""
    return PartitionSpec(SCENARIO_AXIS)


def replicated_spec() -> PartitionSpec:
    """Fully replicated (RL params, fleet estimators broadcast)."""
    return PartitionSpec()


def batch_size(tree) -> int:
    """Leading-axis length of a batched pytree (must be non-empty)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("batch_size: pytree has no array leaves")
    return int(leaves[0].shape[0])


def pad_batch(tree, n_shards: int):
    """Pad ``tree``'s leading axis to a multiple of ``n_shards``.

    Pad rows are copies of row 0 — a *valid* scenario, so the padded
    sweep runs the same control flow everywhere and the pad work is
    simply discarded. Returns ``(padded_tree, mask)`` where ``mask`` is a
    ``(B_padded,)`` bool marking the real rows; when no padding is needed
    the tree is returned untouched.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    b = batch_size(tree)
    pad = (-b) % n_shards
    mask = jnp.arange(b + pad) < b
    if pad == 0:
        return tree, mask
    padded = jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])]),
        tree)
    return padded, mask


def unpad(tree, n_real: int):
    """Slice a (possibly padded) batched pytree back to ``n_real`` rows."""
    return jax.tree.map(lambda x: x[:n_real], tree)
