"""AdamW + gradient clipping + cosine LR schedule, built from scratch.

Optimizer state shards exactly like the parameters (ZeRO-style: m/v inherit
the param PartitionSpecs), so memory per device is (4+4+4)B per param over
the FSDP×TP product.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object   # pytree like params
    v: object


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def cosine_lr(step, *, peak: float = 3e-4, warmup: int = 100,
              total: int = 10_000, floor: float = 3e-5):
    step = step.astype(jnp.float32)
    warm = peak * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(
    params,
    grads,
    state: AdamWState,
    *,
    lr=None,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = cosine_lr(step) if lr is None else lr

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
