"""Deterministic synthetic token pipeline with sharded host feed.

Produces language-model batches whose *distribution* is stable (mixture of
Zipfian unigrams + short-range Markov structure so the loss actually
decreases) and whose contents are a pure function of (seed, step) — exactly
reproducible across restarts and elastic resizes (step-indexed, no
host-local RNG state). ``make_batch_fn`` returns device-placed, sharded
batches for the current mesh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def zipf_logits(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks ** alpha
    return np.log(p / p.sum()).astype(np.float32)


@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def _gen(seed, step, *, batch: int, seq: int, vocab: int):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    base = jax.random.categorical(
        key, jnp.asarray(zipf_logits(vocab)), shape=(batch, seq + 1))
    # short-range structure: token_{t+1} correlates with token_t
    k2 = jax.random.fold_in(key, 1)
    copy_mask = jax.random.bernoulli(k2, 0.3, (batch, seq + 1))
    shifted = jnp.roll(base, 1, axis=1)
    toks = jnp.where(copy_mask, (shifted + 1) % vocab, base)
    return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)


def make_batch_fn(cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 0,
                  batch_override: int | None = None,
                  shardings: dict | None = None):
    B = batch_override or shape.global_batch
    S = shape.seq_len

    def batch_fn(step: int) -> dict:
        toks, labels = _gen(seed, step, batch=B, seq=S,
                            vocab=cfg.vocab_size)
        batch = {"tokens": toks, "labels": labels}
        if cfg.family == "audio":
            key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 7), step)
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encoder.n_frames, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 9), step)
            batch["patch_embeds"] = jax.random.normal(
                key, (B, cfg.encoder.n_frames, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype))
        if shardings is not None:
            batch = {k: jax.device_put(v, shardings[k])
                     for k, v in batch.items()}
        return batch

    return batch_fn
