"""Family-dispatched train/loss steps (the functions the dry-run lowers).

``make_train_step(cfg)`` returns a pure ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` suitable for ``jax.jit`` with NamedShardings.
Microbatch gradient accumulation (``accum``) runs as a ``lax.scan`` over
microbatches — the standard memory/throughput lever at scale.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.train import optimizer as OPT


def model_loss(params, batch: dict, cfg: ModelConfig, *, remat: str = "dots",
               use_flash: bool = False, unroll: bool = False,
               vocab_parallel: bool = False) -> jax.Array:
    fam = cfg.family
    if fam in ("dense", "moe") and vocab_parallel:
        from repro.models.transformer import forward, vocab_parallel_xent
        hidden = forward(params, batch["tokens"], cfg, use_flash=use_flash,
                         remat=remat, unroll=unroll, return_hidden=True)
        return vocab_parallel_xent(hidden, params, batch["labels"], cfg)
    if fam in ("dense", "moe"):
        from repro.models.transformer import loss_fn
        return loss_fn(params, batch["tokens"], batch["labels"], cfg,
                       use_flash=use_flash, remat=remat, unroll=unroll)
    if fam == "vlm":
        from repro.models.transformer import loss_fn
        return loss_fn(params, batch["tokens"], batch["labels"], cfg,
                       prefix_embeds=batch["patch_embeds"],
                       use_flash=use_flash, remat=remat, unroll=unroll)
    if fam == "audio":
        from repro.models import encdec as E
        logits = E.forward(params, batch["tokens"], batch["frames"], cfg,
                           remat=remat, unroll=unroll)
        return _xent(logits, batch["labels"], cfg)
    if fam == "ssm":
        from repro.models import rwkv6 as R
        logits = R.forward(params, batch["tokens"], cfg, remat=remat,
                           unroll=unroll)
        return _xent(logits, batch["labels"], cfg)
    if fam == "hybrid":
        from repro.models import zamba2 as Z
        logits = Z.forward(params, batch["tokens"], cfg, remat=remat,
                           unroll=unroll)
        return _xent(logits, batch["labels"], cfg)
    raise ValueError(fam)


def _xent(logits, labels, cfg) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def init_params(key, cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import init_lm
    elif fam == "audio":
        from repro.models.encdec import init_lm
    elif fam == "ssm":
        from repro.models.rwkv6 import init_lm
    elif fam == "hybrid":
        from repro.models.zamba2 import init_lm
    else:
        raise ValueError(fam)
    return init_lm(key, cfg)


def make_train_step(cfg: ModelConfig, *, accum: int = 1,
                    remat: str = "dots", use_flash: bool = False,
                    donate: bool = True, unroll: bool = False,
                    vocab_parallel: bool = False) -> Callable:
    loss = partial(model_loss, cfg=cfg, remat=remat, use_flash=use_flash,
                   unroll=unroll, vocab_parallel=vocab_parallel)

    def train_step(params, opt_state, batch):
        if accum == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            # microbatch accumulation: batch dims reshaped (accum, b/accum, …)
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), b)

            mb = micro(batch)

            def body(carry, mslice):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss)(params, mslice)
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (l, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero_g), mb)
            l = l / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        new_params, new_opt, gnorm = OPT.update(params, grads, opt_state)
        return new_params, new_opt, {"loss": l, "grad_norm": gnorm}

    return train_step
