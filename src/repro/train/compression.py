"""Error-feedback int8 gradient compression (beyond-paper distributed trick).

Per-tensor symmetric int8 quantization with an error-feedback residual
(Seide et al. / EF-SGD): the quantization error is carried into the next
step, so compression is unbiased in the long run and convergence is
preserved. At 1000+-node scale this cuts cross-pod (DCN) gradient traffic
4× vs f32 / 2× vs bf16; the roofline collective term scales accordingly.

Usage in a train step:
    q, scale, new_resid = compress(grad + resid)
    grad_hat = decompress(q, scale)          # what gets all-reduced
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # f32 scalar per tensor


def compress(x: jax.Array) -> tuple[Compressed, jax.Array]:
    """Returns (compressed, residual error)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return Compressed(q=q, scale=scale), err


def decompress(c: Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def compress_tree(grads, residuals):
    """Apply EF-int8 to every leaf. residuals: same pytree (or zeros)."""
    def one(g, r):
        c, err = compress(g.astype(jnp.float32) + r)
        return decompress(c), err
    pairs = jax.tree.map(one, grads, residuals)
    ghat = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return ghat, resid


def zeros_like_residuals(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
